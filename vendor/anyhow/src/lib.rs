//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no network access, so the small slice of the
//! anyhow API this workspace actually uses is reimplemented here and wired
//! in through a path dependency: [`Error`], [`Result`], the [`Context`]
//! extension trait (on both `Result` and `Option`), and the [`anyhow!`] /
//! [`bail!`] / [`ensure!`] macros.
//!
//! Semantics match upstream where it matters to callers:
//!
//! * `{}` on [`Error`] prints the outermost message;
//! * `{:#}` prints the whole context chain joined with `": "`;
//! * `{:?}` prints the chain in upstream's "Caused by" layout;
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.

use std::fmt;

/// Error type: an outermost message plus the chain of underlying causes,
/// outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error` (same as
// upstream): that is what makes the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_formats() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("reading spec").unwrap_err();
        assert_eq!(format!("{e}"), "reading spec");
        assert_eq!(format!("{e:#}"), "reading spec: missing thing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
