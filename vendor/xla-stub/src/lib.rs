//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real backend needs `libxla_extension`, which the offline build
//! environment does not ship. This stub keeps the whole workspace — the
//! `openacm::runtime` wrapper, the coordinator, and the serving tests —
//! compiling and running:
//!
//! * [`Literal`] is a real, pure-Rust implementation (shape + typed data),
//!   so literal construction/reshaping/decoding works everywhere;
//! * [`PjRtClient::cpu`] succeeds and reports the `"stub-cpu"` platform;
//! * compiling or executing an HLO module returns a clean [`Error`], which
//!   the callers already surface (the serving paths skip gracefully when
//!   AOT artifacts are absent, which is the only time they would execute).
//!
//! Swap this path dependency for the real `xla` crate to run the PJRT
//! serving experiments.

use std::fmt;

/// Stub error type.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

impl Error {
    fn unsupported(what: &str) -> Error {
        Error(format!(
            "{what} is unavailable: openacm was built against the offline xla stub \
             (vendor/xla-stub); link the real xla crate to enable PJRT execution"
        ))
    }
}

type Result<T> = std::result::Result<T, Error>;

/// Typed literal payload.
#[derive(Clone, Debug)]
enum Data {
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
    U8(Vec<u8>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::U8(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Sized + Clone {
    fn wrap(values: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($ty:ty, $variant:ident) => {
        impl NativeType for $ty {
            fn wrap(values: Vec<Self>) -> Data {
                Data::$variant(values)
            }
            fn unwrap(data: &Data) -> Option<Vec<Self>> {
                match data {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(i32, I32);
native!(i64, I64);
native!(f32, F32);
native!(f64, F64);
native!(u8, U8);

/// A host-side array literal: shape + typed data.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            dims: vec![values.len() as i64],
            data: T::wrap(values.to_vec()),
        }
    }

    /// Reshape to the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Decode to a typed vector; errors on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    /// Unwrap a 1-tuple result. The stub never produces tuples, so this is
    /// the identity (it is only reachable after a successful `execute`,
    /// which the stub does not provide).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unsupported("parsing HLO text"))
    }
}

/// XLA computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unsupported("device-to-host transfer"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unsupported("PJRT execution"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unsupported("HLO compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.shape(), &[2, 3]);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[7]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_up_but_execution_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let exe = PjRtLoadedExecutable;
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
