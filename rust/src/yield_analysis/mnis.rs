//! Mean-shifted (minimum-norm) importance sampling — MNIS [29]
//! ("Breaking the simulation barrier: SRAM evaluation through norm
//! minimization", Dolecek et al., ICCAD 2008).
//!
//! Two phases:
//!
//! 1. **Norm minimization** — search the variation space for the failure
//!    point closest to the origin (the dominant saddle point of the tail
//!    integral): directional bisection over random + coordinate directions,
//!    then pattern-search refinement.
//! 2. **Shifted sampling** — draw from N(x*, I) and reweight each sample by
//!    the likelihood ratio `w(y) = φ(y)/φ(y−x*) = exp(−y·x* + |x*|²/2)`;
//!    the estimator is the weighted failure mean, with a sequential stop on
//!    the empirical FoM of the weighted estimator.
//!
//! Every `fails()` evaluation (search *and* sampling) is counted in
//! `sims`, so the Table V speedup comparison against MC is fair.

use super::problem::FailureProblem;
use crate::util::rng::Pcg32;

/// MNIS result.
#[derive(Clone, Debug, Default)]
pub struct MnisResult {
    pub pf: f64,
    pub fom: f64,
    /// Total simulator invocations (search + sampling).
    pub sims: u64,
    /// Norm-minimization evaluations only.
    pub search_sims: u64,
    /// The mean-shift point found by phase 1.
    pub shift: Vec<f64>,
    /// |x*| — the minimum-norm distance to failure, in σ units.
    pub beta: f64,
}

struct CountingProblem<'a, P: FailureProblem> {
    inner: &'a P,
    count: std::sync::atomic::AtomicU64,
}

impl<'a, P: FailureProblem> CountingProblem<'a, P> {
    fn new(inner: &'a P) -> Self {
        Self {
            inner,
            count: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn fails(&self, x: &[f64]) -> bool {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.fails(x)
    }

    fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Phase 1: find a minimum-norm failing point by directional bisection.
fn norm_minimize<P: FailureProblem>(
    problem: &CountingProblem<'_, P>,
    dims: usize,
    seed: u64,
    n_directions: usize,
) -> Option<Vec<f64>> {
    let mut rng = Pcg32::new(seed ^ 0x4D4E4953);
    let t_max = 8.0;
    let mut best: Option<(f64, Vec<f64>)> = None;

    let try_direction = |d: &[f64], problem: &CountingProblem<'_, P>, best: &mut Option<(f64, Vec<f64>)>| {
        let norm = d.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-12 {
            return;
        }
        let dir: Vec<f64> = d.iter().map(|v| v / norm).collect();
        // Skip directions that do not fail even at t_max.
        let at = |t: f64| -> Vec<f64> { dir.iter().map(|v| v * t).collect() };
        // Prune: if we already have a better radius, only probe just below it.
        let probe_t = best.as_ref().map(|(r, _)| *r).unwrap_or(t_max).min(t_max);
        if !problem.fails(&at(probe_t)) {
            return;
        }
        // Bisect the boundary in [0, probe_t].
        let (mut lo, mut hi) = (0.0f64, probe_t);
        for _ in 0..18 {
            let mid = 0.5 * (lo + hi);
            if problem.fails(&at(mid)) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        let r = hi;
        if best.as_ref().map(|(br, _)| r < *br).unwrap_or(true) {
            *best = Some((r, at(r)));
        }
    };

    // Coordinate directions (±eᵢ) first: cheap and often near-optimal for
    // monotone metrics.
    for i in 0..dims {
        for sgn in [1.0, -1.0] {
            let mut d = vec![0.0; dims];
            d[i] = sgn;
            try_direction(&d, problem, &mut best);
        }
    }
    // Random directions.
    let mut d = vec![0.0; dims];
    for _ in 0..n_directions {
        rng.fill_gaussian(&mut d);
        try_direction(&d, problem, &mut best);
    }
    // Pattern-search refinement around the incumbent.
    if let Some((_, x0)) = best.clone() {
        let mut x = x0;
        let mut step = 0.25;
        while step > 0.02 {
            let mut improved = false;
            for i in 0..dims {
                for sgn in [1.0, -1.0] {
                    let mut cand = x.clone();
                    cand[i] += sgn * step;
                    let r_cand = cand.iter().map(|v| v * v).sum::<f64>().sqrt();
                    let r_cur = x.iter().map(|v| v * v).sum::<f64>().sqrt();
                    if r_cand < r_cur && problem.fails(&cand) {
                        x = cand;
                        improved = true;
                    }
                }
            }
            if !improved {
                step *= 0.5;
            }
        }
        let r = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        best = Some((r, x));
    }
    best.map(|(_, x)| x)
}

/// Run MNIS until `fom_target` or `max_sims`.
pub fn run_mnis<P: FailureProblem>(
    problem: &P,
    fom_target: f64,
    max_sims: u64,
    seed: u64,
) -> MnisResult {
    let dims = problem.dims();
    let counting = CountingProblem::new(problem);
    let shift = match norm_minimize(&counting, dims, seed, 24) {
        Some(s) => s,
        None => {
            // No failure found in any direction: report Pf ~ 0.
            return MnisResult {
                pf: 0.0,
                fom: f64::INFINITY,
                sims: counting.count(),
                search_sims: counting.count(),
                shift: vec![0.0; dims],
                beta: f64::INFINITY,
            };
        }
    };
    let search_sims = counting.count();
    let beta = shift.iter().map(|v| v * v).sum::<f64>().sqrt();
    let shift_sq_half = 0.5 * beta * beta;

    let mut rng = Pcg32::new(seed ^ 0x49532e32);
    let mut sum_w = 0f64;
    let mut sum_w2 = 0f64;
    let mut n: u64 = 0;
    let mut fails: u64 = 0;
    let mut y = vec![0f64; dims];
    let mut z = vec![0f64; dims];
    let check_every = 500u64;
    while counting.count() < max_sims {
        rng.fill_gaussian(&mut z);
        for i in 0..dims {
            y[i] = shift[i] + z[i];
        }
        n += 1;
        if counting.fails(&y) {
            fails += 1;
            let dot: f64 = y.iter().zip(&shift).map(|(a, b)| a * b).sum();
            let w = (-dot + shift_sq_half).exp();
            sum_w += w;
            sum_w2 += w * w;
        }
        if n % check_every == 0 && fails >= 10 {
            let pf = sum_w / n as f64;
            let var = (sum_w2 / n as f64 - pf * pf) / n as f64;
            let fom = var.max(0.0).sqrt() / pf;
            if fom <= fom_target {
                return MnisResult {
                    pf,
                    fom,
                    sims: counting.count(),
                    search_sims,
                    shift,
                    beta,
                };
            }
        }
    }
    let pf = if n > 0 { sum_w / n as f64 } else { 0.0 };
    let var = if n > 0 {
        (sum_w2 / n as f64 - pf * pf) / n as f64
    } else {
        f64::INFINITY
    };
    MnisResult {
        pf,
        fom: if pf > 0.0 {
            var.max(0.0).sqrt() / pf
        } else {
            f64::INFINITY
        },
        sims: counting.count(),
        search_sims,
        shift,
        beta,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yield_analysis::mc::run_mc;
    use crate::yield_analysis::problem::LinearProblem;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn estimates_known_tail_pf() {
        // Pf = Φ(−3.5) ≈ 2.33e-4 — deep enough that MC at FoM 0.1 would
        // need ~400k sims.
        let p = LinearProblem::new(vec![1.0, -0.5, 0.25, 0.1], 3.5);
        let r = run_mnis(&p, 0.1, 300_000, 11);
        let exact = p.exact_pf();
        assert!(
            (r.pf - exact).abs() / exact < 0.35,
            "pf {} vs exact {exact}",
            r.pf
        );
        assert!(r.fom <= 0.1 + 1e-9, "fom {}", r.fom);
        // The min-norm point of a linear boundary is at distance β.
        assert!((r.beta - 3.5).abs() < 0.25, "beta {}", r.beta);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn mnis_beats_mc_on_sims_for_same_fom() {
        let p = LinearProblem::new(vec![0.8, 0.6], 3.0); // Pf ≈ 1.35e-3
        let mc = run_mc(&p, 0.15, 2_000_000, 5, 4);
        let is = run_mnis(&p, 0.15, 2_000_000, 5);
        assert!(mc.fom <= 0.15 + 1e-9 && is.fom <= 0.15 + 1e-9);
        let speedup = mc.sims as f64 / is.sims as f64;
        assert!(
            speedup > 4.0,
            "expected >4x speedup, got {speedup:.1} ({} vs {})",
            mc.sims,
            is.sims
        );
    }

    #[test]
    fn handles_unreachable_failure_region() {
        // β = 12: nothing fails within the search radius → Pf 0 gracefully.
        let p = LinearProblem::new(vec![1.0], 12.0);
        let r = run_mnis(&p, 0.1, 10_000, 3);
        assert_eq!(r.pf, 0.0);
        assert!(r.fom.is_infinite());
    }

    #[test]
    fn deterministic_for_seed() {
        let p = LinearProblem::new(vec![1.0, 1.0], 3.0);
        let a = run_mnis(&p, 0.2, 100_000, 9);
        let b = run_mnis(&p, 0.2, 100_000, 9);
        assert_eq!(a.sims, b.sims);
        assert!((a.pf - b.pf).abs() < 1e-15);
    }

    #[test]
    fn search_cost_is_counted() {
        let p = LinearProblem::new(vec![1.0, 1.0], 3.0);
        let r = run_mnis(&p, 0.2, 100_000, 13);
        assert!(r.search_sims > 0);
        assert!(r.sims > r.search_sims);
    }
}
