//! Variation-aware yield analysis (paper §III-D item 1 and §V-C, Table V):
//! plain Monte-Carlo versus Mean-shifted (minimum-norm) Importance Sampling
//! (MNIS [29]) on the SRAM cell's 6-dimensional local-mismatch space.
//!
//! The failure metric is the OpenYield-style combination of read-stability
//! (read SNM below a critical margin), writeability (write margin below
//! zero) and access-time (bit-line development too slow for the sense
//! window given the sampled read current and the array's BL/WL loading —
//! the "trimmed N×2 array with full WL parasitics" setup of Table V).
//!
//! [`functional`] lifts the cell-level failure probabilities to the system
//! level: Monte-Carlo over weight-storage bit corruption, scored against an
//! arithmetic accuracy criterion on the gate netlist, with 64 corruption
//! samples per bit-parallel sweep.

pub mod problem;
pub mod mc;
pub mod mnis;
pub mod functional;
pub mod cli;

pub use functional::{run_functional_mc, run_functional_mc_cached, FunctionalYieldProblem};
pub use mc::{run_mc, McResult};
pub use mnis::{run_mnis, MnisResult};
pub use problem::{FailureProblem, SramYieldProblem};
