//! Functional yield of the DCiM datapath under SRAM read-bit corruption.
//!
//! The analog yield problems ([`super::problem`]) ask "does the bit cell
//! still read correctly?"; this module asks the system-level question the
//! compiler actually cares about: *if* weight-storage bits are corrupted
//! with some per-column probability (derived from the cell-level Pf), does
//! the macro's arithmetic still meet its accuracy spec on a given workload?
//!
//! Monte-Carlo over corruption patterns rides the bit-parallel gate engine:
//! the lanes of each bit-plane group carry *independent corruption
//! samples* (rather than time steps), so one topological sweep per
//! workload pair scores `64 × plane-width` Monte-Carlo samples at once
//! (width from [`crate::util::simd`]; see DESIGN.md §"SIMD kernels").
//! Sample blocks are distributed across worker threads with per-64-block
//! forked RNG streams — the forking is *independent of the sweep width*,
//! so results are bit-identical for any thread count and any SIMD tier.

use super::mc::McResult;
use crate::gates::Netlist;
use crate::store::{DesignPointRecord, DesignPointStore, KeyBuilder, YieldStats};
use crate::util::rng::Pcg32;
use crate::util::threadpool::parallel_fold;

/// One functional-yield question: netlist + workload + failure criterion.
pub struct FunctionalYieldProblem<'a> {
    /// Multiplier netlist (inputs `a[0..bits)`, `b[0..bits)`, output bus).
    pub nl: &'a Netlist,
    /// Operand width.
    pub bits: usize,
    /// Per-column probability that a read of stored-operand bit `i` flips
    /// (length `bits`; column 0 = LSB).
    pub flip_prob: Vec<f64>,
    /// Workload pairs `(a, b)` where `a` is the stored (corruptible) operand.
    pub workload: Vec<(u64, u64)>,
    /// A sample fails when `|p̂ − a·b| / p_max` exceeds this on any pair.
    pub err_threshold: f64,
}

impl<'a> FunctionalYieldProblem<'a> {
    pub fn new(
        nl: &'a Netlist,
        bits: usize,
        flip_prob: Vec<f64>,
        workload: Vec<(u64, u64)>,
        err_threshold: f64,
    ) -> Self {
        assert_eq!(flip_prob.len(), bits, "one flip probability per column");
        assert_eq!(nl.inputs().len(), 2 * bits, "2-operand netlist expected");
        assert!(!workload.is_empty(), "empty workload");
        Self {
            nl,
            bits,
            flip_prob,
            workload,
            err_threshold,
        }
    }

    /// Evaluate any number of corruption samples — sample `w·64 + l` rides
    /// lane `l` of plane-group word `w`, so the whole batch is scored in
    /// `ceil(len/64)`-word-wide sweeps ([`Netlist::eval_wide_into`]) —
    /// over the whole workload; returns how many samples *fail*. With
    /// ≤ 64 masks this is exactly the original one-word sweep; wider
    /// batches are bit-identical to evaluating the same masks 64 at a
    /// time, because the per-lane pass/fail decision only reads that
    /// lane's own bits.
    pub fn failing_count(&self, masks: &[u64]) -> u64 {
        let lanes = masks.len();
        assert!(lanes > 0, "at least one corruption sample");
        let words = lanes.div_ceil(64);
        let p_max = {
            let top = ((1u64 << self.bits) - 1) as f64;
            top * top
        };
        let mut assignment = vec![0u64; 2 * self.bits * words];
        let mut vals = Vec::new();
        // Per-word live-lane masks: full words, then the final partial one.
        let live: Vec<u64> = (0..words)
            .map(|w| {
                let bits = (lanes - w * 64).min(64);
                if bits == 64 {
                    u64::MAX
                } else {
                    (1u64 << bits) - 1
                }
            })
            .collect();
        let mut failing = vec![0u64; words];
        let outs = self.nl.outputs();
        for &(a, b) in &self.workload {
            if failing.iter().zip(&live).all(|(f, l)| f == l) {
                break; // every sample already failed
            }
            for i in 0..self.bits {
                let a_bit = (a >> i) & 1;
                let b_word = if (b >> i) & 1 == 1 { u64::MAX } else { 0 };
                for w in 0..words {
                    let lo = w * 64;
                    let hi = (lo + 64).min(lanes);
                    let mut word = 0u64;
                    for (l, &mask) in masks[lo..hi].iter().enumerate() {
                        if (a_bit ^ ((mask >> i) & 1)) == 1 {
                            word |= 1u64 << l;
                        }
                    }
                    assignment[i * words + w] = word;
                    assignment[(self.bits + i) * words + w] = b_word & live[w];
                }
            }
            self.nl.eval_wide_into(&assignment, words, &mut vals);
            let exact = (a * b) as i64;
            for l in 0..lanes {
                let (w, bit) = (l / 64, l % 64);
                if failing[w] & (1u64 << bit) != 0 {
                    continue;
                }
                let p = outs
                    .iter()
                    .enumerate()
                    .fold(0u64, |acc, (i, (_, id))| {
                        acc | (((vals[id.idx() * words + w] >> bit) & 1) << i)
                    });
                let err = (p as i64 - exact).unsigned_abs() as f64 / p_max;
                if err > self.err_threshold {
                    failing[w] |= 1u64 << bit;
                }
            }
        }
        failing.iter().map(|f| f.count_ones() as u64).sum()
    }
}

/// Monte-Carlo functional yield: `samples` corruption patterns, evaluated
/// `64 × plane-width` per gate-level sweep (width from
/// [`crate::util::simd::detect`]), distributed across `threads` workers.
/// Bit-identical for any width and thread count: the RNG streams stay
/// forked per 64-sample block no matter how many blocks one sweep scores.
pub fn run_functional_mc(
    problem: &FunctionalYieldProblem,
    samples: u64,
    seed: u64,
    threads: usize,
) -> McResult {
    run_functional_mc_words(
        problem,
        samples,
        seed,
        threads,
        crate::util::simd::detect().plane_words(),
    )
}

/// [`run_functional_mc`] with an explicitly pinned plane-group width
/// (`words == 1` is the scalar-oracle path). Exposed for the SIMD
/// equivalence tests.
#[doc(hidden)]
pub fn run_functional_mc_words(
    problem: &FunctionalYieldProblem,
    samples: u64,
    seed: u64,
    threads: usize,
    words: usize,
) -> McResult {
    if samples == 0 {
        return McResult {
            pf: 0.0,
            fom: f64::INFINITY,
            sims: 0,
            failures: 0,
        };
    }
    let words = words.max(1) as u64;
    let blocks = samples.div_ceil(64);
    // One work item = a *superblock* of up to `words` consecutive
    // 64-sample blocks, scored in a single plane-group sweep. Each block
    // still draws its masks from its own per-block forked RNG stream, so
    // the sampled corruption patterns — and therefore the whole estimate —
    // are bit-identical to the scalar (words = 1) path.
    let groups = blocks.div_ceil(words);
    let failures = parallel_fold(
        groups as usize,
        threads.max(1),
        |group| {
            let b_lo = group as u64 * words;
            let b_hi = (b_lo + words).min(blocks);
            let mut masks = Vec::with_capacity(((b_hi - b_lo) * 64) as usize);
            for block in b_lo..b_hi {
                // Fork on the bare block index: distinct per block by
                // construction (an OR-ed tag would alias high indices).
                let mut rng = Pcg32::new(seed ^ 0xFC17_0000_0000_0000).fork(block);
                let lanes = (samples - block * 64).min(64) as usize;
                for _ in 0..lanes {
                    let mut mask = 0u64;
                    for (i, &p) in problem.flip_prob.iter().enumerate() {
                        if rng.next_f64() < p {
                            mask |= 1u64 << i;
                        }
                    }
                    masks.push(mask);
                }
            }
            problem.failing_count(&masks)
        },
        |a, b| a + b,
    );
    let pf = failures as f64 / samples.max(1) as f64;
    let fom = if pf > 0.0 {
        ((1.0 - pf) / (pf * samples as f64)).sqrt()
    } else {
        f64::INFINITY
    };
    McResult {
        pf,
        fom,
        sims: samples,
        failures,
    }
}

/// [`run_functional_mc`] through the design-point store: the key covers
/// the netlist structure, the corruption model (per-column flip
/// probabilities), the workload, the failure criterion and the MC budget
/// `(samples, seed)` — everything the estimate depends on — so repeated
/// yield sweeps are served from disk through the same record type as the
/// DSE and PPA caches.
pub fn run_functional_mc_cached(
    problem: &FunctionalYieldProblem,
    samples: u64,
    seed: u64,
    threads: usize,
    store: Option<&DesignPointStore>,
) -> McResult {
    let Some(store) = store else {
        return run_functional_mc(problem, samples, seed, threads);
    };
    let mut kb = KeyBuilder::new("fyield/1");
    kb.netlist(problem.nl)
        .u32(problem.bits as u32)
        .f64s(&problem.flip_prob)
        .pairs(&problem.workload)
        .f64(problem.err_threshold)
        .u64(samples)
        .u64(seed);
    let key = kb.finish();
    let (rec, _hit) = store.get_or_put_with(key, || DesignPointRecord {
        family: problem.nl.name.clone(),
        bits: problem.bits as u32,
        n_ops: problem.workload.len() as u64,
        seed,
        fyield: Some(YieldStats::from_mc(&run_functional_mc(
            problem, samples, seed, threads,
        ))),
        ..Default::default()
    });
    match rec.fyield {
        Some(y) => y.to_mc(),
        None => run_functional_mc(problem, samples, seed, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn workload(bits: usize, n: usize, seed: u64) -> Vec<(u64, u64)> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                (
                    rng.below(1 << bits) as u64,
                    rng.below(1 << bits) as u64,
                )
            })
            .collect()
    }

    #[test]
    fn clean_reads_never_fail() {
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.0; 4], workload(4, 20, 1), 1e-6);
        let r = run_functional_mc(&p, 500, 42, 2);
        assert_eq!(r.failures, 0);
        assert_eq!(r.pf, 0.0);
        assert_eq!(r.sims, 500);
    }

    #[test]
    fn certain_msb_flip_fails_every_sample() {
        let nl = crate::mult::pptree::build_exact(4);
        // MSB always flips; workload guarantees the MSB of `a` matters.
        let mut fp = vec![0.0; 4];
        fp[3] = 1.0;
        let p = FunctionalYieldProblem::new(&nl, 4, fp, vec![(0b1000, 15)], 1e-3);
        let r = run_functional_mc(&p, 200, 7, 3);
        assert_eq!(r.failures, 200);
        assert_eq!(r.pf, 1.0);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.05; 4], workload(4, 30, 3), 5e-3);
        let a = run_functional_mc(&p, 1000, 99, 1);
        let b = run_functional_mc(&p, 1000, 99, 4);
        assert_eq!(a.failures, b.failures);
        assert_eq!(a.pf, b.pf);
    }

    #[test]
    fn plane_width_does_not_change_the_estimate() {
        // The per-64-block RNG forking is width-independent, so wide
        // sweeps must reproduce the scalar path bit for bit — including
        // with a partial final block (1000 % 64 != 0) and for a width
        // that doesn't divide the block count evenly.
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.05; 4], workload(4, 30, 3), 5e-3);
        let narrow = run_functional_mc_words(&p, 1000, 99, 2, 1);
        for words in [2usize, 3, 4] {
            let wide = run_functional_mc_words(&p, 1000, 99, 2, words);
            assert_eq!(narrow.failures, wide.failures, "words={words}");
            assert_eq!(narrow.pf.to_bits(), wide.pf.to_bits(), "words={words}");
            assert_eq!(narrow.sims, wide.sims);
        }
    }

    #[test]
    fn cached_mc_matches_uncached_and_hits_second_time() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_fyield_cache_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = crate::store::DesignPointStore::open(&dir).unwrap();
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.05; 4], workload(4, 30, 3), 5e-3);
        let plain = run_functional_mc(&p, 640, 99, 2);
        let miss = run_functional_mc_cached(&p, 640, 99, 2, Some(&store));
        let hit = run_functional_mc_cached(&p, 640, 99, 2, Some(&store));
        for r in [&miss, &hit] {
            assert_eq!(r.failures, plain.failures);
            assert_eq!(r.pf.to_bits(), plain.pf.to_bits());
            assert_eq!(r.sims, plain.sims);
        }
        // A different corruption model must not alias the record.
        let p2 = FunctionalYieldProblem::new(&nl, 4, vec![0.06; 4], workload(4, 30, 3), 5e-3);
        let _ = run_functional_mc_cached(&p2, 640, 99, 2, Some(&store));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 2, 2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lenient_threshold_tolerates_lsb_noise() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut fp = vec![0.0; 4];
        fp[0] = 1.0; // LSB always flips: worst product error 15 of p_max 225
        let wl = workload(4, 10, 5);
        let strict = FunctionalYieldProblem::new(&nl, 4, fp.clone(), wl.clone(), 1e-6);
        let lenient = FunctionalYieldProblem::new(&nl, 4, fp, wl, 0.5);
        let rs = run_functional_mc(&strict, 64, 11, 2);
        let rl = run_functional_mc(&lenient, 64, 11, 2);
        assert!(rs.failures > 0, "strict criterion must catch LSB flips");
        assert_eq!(rl.failures, 0, "lenient criterion must tolerate them");
    }
}
