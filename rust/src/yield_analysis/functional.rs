//! Functional yield of the DCiM datapath under SRAM read-bit corruption.
//!
//! The analog yield problems ([`super::problem`]) ask "does the bit cell
//! still read correctly?"; this module asks the system-level question the
//! compiler actually cares about: *if* weight-storage bits are corrupted
//! with some per-column probability (derived from the cell-level Pf), does
//! the macro's arithmetic still meet its accuracy spec on a given workload?
//!
//! Monte-Carlo over corruption patterns rides the bit-parallel gate engine:
//! the 64 lanes of each bit-plane carry 64 *independent corruption samples*
//! (rather than 64 time steps), so one topological sweep per workload pair
//! scores 64 Monte-Carlo samples at once. Sample blocks are distributed
//! across worker threads with per-block forked RNG streams, so results are
//! deterministic for any thread count.

use super::mc::McResult;
use crate::gates::Netlist;
use crate::store::{DesignPointRecord, DesignPointStore, KeyBuilder, YieldStats};
use crate::util::rng::Pcg32;
use crate::util::threadpool::parallel_fold;

/// One functional-yield question: netlist + workload + failure criterion.
pub struct FunctionalYieldProblem<'a> {
    /// Multiplier netlist (inputs `a[0..bits)`, `b[0..bits)`, output bus).
    pub nl: &'a Netlist,
    /// Operand width.
    pub bits: usize,
    /// Per-column probability that a read of stored-operand bit `i` flips
    /// (length `bits`; column 0 = LSB).
    pub flip_prob: Vec<f64>,
    /// Workload pairs `(a, b)` where `a` is the stored (corruptible) operand.
    pub workload: Vec<(u64, u64)>,
    /// A sample fails when `|p̂ − a·b| / p_max` exceeds this on any pair.
    pub err_threshold: f64,
}

impl<'a> FunctionalYieldProblem<'a> {
    pub fn new(
        nl: &'a Netlist,
        bits: usize,
        flip_prob: Vec<f64>,
        workload: Vec<(u64, u64)>,
        err_threshold: f64,
    ) -> Self {
        assert_eq!(flip_prob.len(), bits, "one flip probability per column");
        assert_eq!(nl.inputs().len(), 2 * bits, "2-operand netlist expected");
        assert!(!workload.is_empty(), "empty workload");
        Self {
            nl,
            bits,
            flip_prob,
            workload,
            err_threshold,
        }
    }

    /// Evaluate up to 64 corruption samples (one per lane of `masks`) over
    /// the whole workload; returns a bitmask of *failing* lanes.
    pub fn failing_lanes(&self, masks: &[u64]) -> u64 {
        let lanes = masks.len();
        assert!(0 < lanes && lanes <= 64);
        let p_max = {
            let top = ((1u64 << self.bits) - 1) as f64;
            top * top
        };
        let mut assignment = vec![0u64; 2 * self.bits];
        let mut vals = Vec::new();
        let mut failing = 0u64;
        let all = if lanes == 64 {
            u64::MAX
        } else {
            (1u64 << lanes) - 1
        };
        for &(a, b) in &self.workload {
            if failing == all {
                break; // every lane already failed
            }
            for i in 0..self.bits {
                let a_bit = (a >> i) & 1;
                let mut word = 0u64;
                for (l, &mask) in masks.iter().enumerate() {
                    if (a_bit ^ ((mask >> i) & 1)) == 1 {
                        word |= 1u64 << l;
                    }
                }
                assignment[i] = word;
                assignment[self.bits + i] = if (b >> i) & 1 == 1 { all } else { 0 };
            }
            self.nl.eval_u64_into(&assignment, &mut vals);
            let exact = (a * b) as i64;
            let outs = self.nl.outputs();
            for l in 0..lanes {
                if failing & (1u64 << l) != 0 {
                    continue;
                }
                let p = outs
                    .iter()
                    .enumerate()
                    .fold(0u64, |acc, (i, (_, id))| {
                        acc | (((vals[id.idx()] >> l) & 1) << i)
                    });
                let err = (p as i64 - exact).unsigned_abs() as f64 / p_max;
                if err > self.err_threshold {
                    failing |= 1u64 << l;
                }
            }
        }
        failing
    }
}

/// Monte-Carlo functional yield: `samples` corruption patterns, evaluated
/// 64 per gate-level sweep, distributed across `threads` workers.
pub fn run_functional_mc(
    problem: &FunctionalYieldProblem,
    samples: u64,
    seed: u64,
    threads: usize,
) -> McResult {
    if samples == 0 {
        return McResult {
            pf: 0.0,
            fom: f64::INFINITY,
            sims: 0,
            failures: 0,
        };
    }
    let blocks = samples.div_ceil(64);
    let failures = parallel_fold(
        blocks as usize,
        threads.max(1),
        |block| {
            // Fork on the bare block index: distinct per block by
            // construction (an OR-ed tag would alias high block indices).
            let mut rng = Pcg32::new(seed ^ 0xFC17_0000_0000_0000).fork(block as u64);
            let lanes = (samples - block as u64 * 64).min(64) as usize;
            let mut masks = Vec::with_capacity(lanes);
            for _ in 0..lanes {
                let mut mask = 0u64;
                for (i, &p) in problem.flip_prob.iter().enumerate() {
                    if rng.next_f64() < p {
                        mask |= 1u64 << i;
                    }
                }
                masks.push(mask);
            }
            problem.failing_lanes(&masks).count_ones() as u64
        },
        |a, b| a + b,
    );
    let pf = failures as f64 / samples.max(1) as f64;
    let fom = if pf > 0.0 {
        ((1.0 - pf) / (pf * samples as f64)).sqrt()
    } else {
        f64::INFINITY
    };
    McResult {
        pf,
        fom,
        sims: samples,
        failures,
    }
}

/// [`run_functional_mc`] through the design-point store: the key covers
/// the netlist structure, the corruption model (per-column flip
/// probabilities), the workload, the failure criterion and the MC budget
/// `(samples, seed)` — everything the estimate depends on — so repeated
/// yield sweeps are served from disk through the same record type as the
/// DSE and PPA caches.
pub fn run_functional_mc_cached(
    problem: &FunctionalYieldProblem,
    samples: u64,
    seed: u64,
    threads: usize,
    store: Option<&DesignPointStore>,
) -> McResult {
    let Some(store) = store else {
        return run_functional_mc(problem, samples, seed, threads);
    };
    let mut kb = KeyBuilder::new("fyield/1");
    kb.netlist(problem.nl)
        .u32(problem.bits as u32)
        .f64s(&problem.flip_prob)
        .pairs(&problem.workload)
        .f64(problem.err_threshold)
        .u64(samples)
        .u64(seed);
    let key = kb.finish();
    let (rec, _hit) = store.get_or_put_with(key, || DesignPointRecord {
        family: problem.nl.name.clone(),
        bits: problem.bits as u32,
        n_ops: problem.workload.len() as u64,
        seed,
        fyield: Some(YieldStats::from_mc(&run_functional_mc(
            problem, samples, seed, threads,
        ))),
        ..Default::default()
    });
    match rec.fyield {
        Some(y) => y.to_mc(),
        None => run_functional_mc(problem, samples, seed, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn workload(bits: usize, n: usize, seed: u64) -> Vec<(u64, u64)> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                (
                    rng.below(1 << bits) as u64,
                    rng.below(1 << bits) as u64,
                )
            })
            .collect()
    }

    #[test]
    fn clean_reads_never_fail() {
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.0; 4], workload(4, 20, 1), 1e-6);
        let r = run_functional_mc(&p, 500, 42, 2);
        assert_eq!(r.failures, 0);
        assert_eq!(r.pf, 0.0);
        assert_eq!(r.sims, 500);
    }

    #[test]
    fn certain_msb_flip_fails_every_sample() {
        let nl = crate::mult::pptree::build_exact(4);
        // MSB always flips; workload guarantees the MSB of `a` matters.
        let mut fp = vec![0.0; 4];
        fp[3] = 1.0;
        let p = FunctionalYieldProblem::new(&nl, 4, fp, vec![(0b1000, 15)], 1e-3);
        let r = run_functional_mc(&p, 200, 7, 3);
        assert_eq!(r.failures, 200);
        assert_eq!(r.pf, 1.0);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.05; 4], workload(4, 30, 3), 5e-3);
        let a = run_functional_mc(&p, 1000, 99, 1);
        let b = run_functional_mc(&p, 1000, 99, 4);
        assert_eq!(a.failures, b.failures);
        assert_eq!(a.pf, b.pf);
    }

    #[test]
    fn cached_mc_matches_uncached_and_hits_second_time() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_fyield_cache_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = crate::store::DesignPointStore::open(&dir).unwrap();
        let nl = crate::mult::pptree::build_exact(4);
        let p = FunctionalYieldProblem::new(&nl, 4, vec![0.05; 4], workload(4, 30, 3), 5e-3);
        let plain = run_functional_mc(&p, 640, 99, 2);
        let miss = run_functional_mc_cached(&p, 640, 99, 2, Some(&store));
        let hit = run_functional_mc_cached(&p, 640, 99, 2, Some(&store));
        for r in [&miss, &hit] {
            assert_eq!(r.failures, plain.failures);
            assert_eq!(r.pf.to_bits(), plain.pf.to_bits());
            assert_eq!(r.sims, plain.sims);
        }
        // A different corruption model must not alias the record.
        let p2 = FunctionalYieldProblem::new(&nl, 4, vec![0.06; 4], workload(4, 30, 3), 5e-3);
        let _ = run_functional_mc_cached(&p2, 640, 99, 2, Some(&store));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 2, 2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lenient_threshold_tolerates_lsb_noise() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut fp = vec![0.0; 4];
        fp[0] = 1.0; // LSB always flips: worst product error 15 of p_max 225
        let wl = workload(4, 10, 5);
        let strict = FunctionalYieldProblem::new(&nl, 4, fp.clone(), wl.clone(), 1e-6);
        let lenient = FunctionalYieldProblem::new(&nl, 4, fp, wl, 0.5);
        let rs = run_functional_mc(&strict, 64, 11, 2);
        let rl = run_functional_mc(&lenient, 64, 11, 2);
        assert!(rs.failures > 0, "strict criterion must catch LSB flips");
        assert_eq!(rl.failures, 0, "lenient criterion must tolerate them");
    }
}
