//! `openacm yield` — reproduce Table V: MC vs MNIS yield analysis on
//! trimmed N×2 SRAM arrays.

use anyhow::Result;

use super::{run_mc, run_mnis, SramYieldProblem};
use crate::bench::harness::{sci, Table};
use crate::util::cli::Args;
use crate::util::threadpool::ThreadPool;

/// One Table V row.
#[derive(Clone, Debug)]
pub struct YieldRow {
    pub size: usize,
    pub mc_pf: f64,
    pub mc_fom: f64,
    pub mc_sims: u64,
    pub mnis_pf: f64,
    pub mnis_fom: f64,
    pub mnis_sims: u64,
}

impl YieldRow {
    pub fn speedup(&self) -> f64 {
        self.mc_sims as f64 / self.mnis_sims.max(1) as f64
    }
}

/// Run the comparison for one trimmed array size.
pub fn run_size(
    rows: usize,
    fom_target: f64,
    mc_max: u64,
    mnis_max: u64,
    seed: u64,
    threads: usize,
) -> YieldRow {
    let problem = SramYieldProblem::table5(rows);
    let mc = run_mc(&problem, fom_target, mc_max, seed, threads);
    let is = run_mnis(&problem, fom_target, mnis_max, seed);
    YieldRow {
        size: rows,
        mc_pf: mc.pf,
        mc_fom: mc.fom,
        mc_sims: mc.sims,
        mnis_pf: is.pf,
        mnis_fom: is.fom,
        mnis_sims: is.sims,
    }
}

/// Build the Table V table for a list of sizes.
pub fn table5(rows: &[YieldRow]) -> Table {
    let mut t = Table::new(
        "Table V: MC vs MNIS yield analysis (trimmed Nx2 arrays)",
        &[
            "Size", "MC Pf", "MC FoM", "MC #Sim", "MNIS Pf", "MNIS FoM", "MNIS #Sim", "Speedup",
        ],
    );
    for r in rows {
        t.row(&[
            format!("{}x2", r.size),
            sci(r.mc_pf),
            format!("{:.2}", r.mc_fom),
            r.mc_sims.to_string(),
            sci(r.mnis_pf),
            format!("{:.2}", r.mnis_fom),
            r.mnis_sims.to_string(),
            format!("{:.1}x", r.speedup()),
        ]);
    }
    t
}

pub fn cmd_yield(args: &Args) -> Result<()> {
    let fom = args.f64_or("fom", 0.05)?;
    let mc_max = args.u64_or("mc-max", 500_000)?;
    let mnis_max = args.u64_or("mnis-max", 50_000)?;
    let seed = args.u64_or("seed", 2026)?;
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    let sizes: Vec<usize> = match args.get("size") {
        Some(s) => vec![s.parse()?],
        None => vec![16, 32, 64],
    };
    let mut out = Vec::new();
    for rows in sizes {
        eprintln!("running {rows}x2 (MC then MNIS)...");
        out.push(run_size(rows, fom, mc_max, mnis_max, seed, threads));
    }
    table5(&out).print();
    println!(
        "\npaper Table V reference: 16x2 Pf 1.6E-4 (55,600 sims) vs MNIS 3.2E-4 (2,985) = 18x;\n\
         32x2 6.4E-2 (22,900) vs 1.7E-2 (2,260) = 10x; 64x2 3.9E-3 (41,500) vs 1.5E-3 (4,260) = 9.7x"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn small_yield_run_produces_consistent_estimates() {
        // Loose FoM + small caps so the test runs in seconds.
        let row = run_size(16, 0.5, 4_000, 4_000, 7, 2);
        assert!(row.mc_sims > 0 && row.mnis_sims > 0);
        // Both estimators must agree on the Pf decade when both found
        // failures.
        if row.mc_pf > 0.0 && row.mnis_pf > 0.0 {
            let ratio = row.mc_pf / row.mnis_pf;
            assert!(
                (0.02..50.0).contains(&ratio),
                "mc {} vs mnis {}",
                row.mc_pf,
                row.mnis_pf
            );
        }
    }

    #[test]
    fn table_renders_all_columns() {
        let t = table5(&[YieldRow {
            size: 16,
            mc_pf: 1.6e-4,
            mc_fom: 0.1,
            mc_sims: 55_600,
            mnis_pf: 3.2e-4,
            mnis_fom: 0.05,
            mnis_sims: 2_985,

        }]);
        let s = t.render();
        assert!(s.contains("16x2"));
        assert!(s.contains("18.6x")); // 55600/2985
    }
}
