//! Plain Monte-Carlo yield estimation with FoM-based sequential stopping.
//!
//! FoM = std(P̂f)/P̂f (the paper's Table V figure of merit). For a Bernoulli
//! estimator, std(P̂f) = sqrt(Pf(1−Pf)/N), so the run stops once the
//! *empirical* FoM reaches the target (or the simulation budget is spent).

use super::problem::FailureProblem;
use crate::util::rng::Pcg32;
use crate::util::threadpool::parallel_fold;

/// Monte-Carlo result.
#[derive(Clone, Copy, Debug, Default)]
pub struct McResult {
    pub pf: f64,
    pub fom: f64,
    pub sims: u64,
    pub failures: u64,
}

/// Run MC until `fom_target` is reached or `max_sims` is exhausted.
/// Deterministic for a given seed; runs in `threads` parallel chunks.
pub fn run_mc<P: FailureProblem>(
    problem: &P,
    fom_target: f64,
    max_sims: u64,
    seed: u64,
    threads: usize,
) -> McResult {
    let dims = problem.dims();
    let chunk: u64 = 1000;
    let mut total: u64 = 0;
    let mut fails: u64 = 0;
    let mut round = 0u64;
    while total < max_sims {
        let chunks = threads.max(1) as u64;
        let this_round: u64 = (chunk * chunks).min(max_sims - total);
        let per_chunk = this_round.div_ceil(chunks);
        let new_fails = parallel_fold(
            chunks as usize,
            threads,
            |ci| {
                let mut rng =
                    Pcg32::new(seed ^ (round << 20) ^ ci as u64).fork(0x4D43 ^ ci as u64);
                let mut x = vec![0f64; dims];
                let n = per_chunk.min(this_round.saturating_sub(ci as u64 * per_chunk));
                let mut f = 0u64;
                for _ in 0..n {
                    rng.fill_gaussian(&mut x);
                    if problem.fails(&x) {
                        f += 1;
                    }
                }
                f
            },
            |a, b| a + b,
        );
        fails += new_fails;
        total += this_round;
        round += 1;
        if fails >= 10 {
            let pf = fails as f64 / total as f64;
            let fom = ((1.0 - pf) / (pf * total as f64)).sqrt();
            if fom <= fom_target {
                return McResult {
                    pf,
                    fom,
                    sims: total,
                    failures: fails,
                };
            }
        }
    }
    let pf = fails as f64 / total.max(1) as f64;
    let fom = if pf > 0.0 {
        ((1.0 - pf) / (pf * total as f64)).sqrt()
    } else {
        f64::INFINITY
    };
    McResult {
        pf,
        fom,
        sims: total,
        failures: fails,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yield_analysis::problem::LinearProblem;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn estimates_known_pf() {
        // Pf = Φ(−2) ≈ 2.275e-2.
        let p = LinearProblem::new(vec![1.0, 0.5, -0.25], 2.0);
        let r = run_mc(&p, 0.1, 200_000, 42, 4);
        let exact = p.exact_pf();
        assert!(
            (r.pf - exact).abs() / exact < 0.3,
            "pf {} vs exact {exact}",
            r.pf
        );
        assert!(r.fom <= 0.1 + 1e-9);
    }

    #[test]
    fn deterministic_for_seed() {
        let p = LinearProblem::new(vec![1.0], 1.5);
        let a = run_mc(&p, 0.2, 20_000, 7, 2);
        let b = run_mc(&p, 0.2, 20_000, 7, 2);
        assert_eq!(a.failures, b.failures);
        assert_eq!(a.sims, b.sims);
    }

    #[test]
    fn budget_cap_respected() {
        // Pf ~ Φ(−5) ≈ 2.9e-7: cannot hit FoM 0.1 within 10k sims.
        let p = LinearProblem::new(vec![1.0], 5.0);
        let r = run_mc(&p, 0.1, 10_000, 1, 2);
        assert_eq!(r.sims, 10_000);
        assert!(r.fom > 0.1 || r.failures == 0);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn rarer_events_need_more_sims() {
        let easy = run_mc(&LinearProblem::new(vec![1.0], 1.0), 0.1, 500_000, 3, 4);
        let hard = run_mc(&LinearProblem::new(vec![1.0], 2.5), 0.1, 500_000, 3, 4);
        assert!(hard.sims > easy.sims, "hard {} <= easy {}", hard.sims, easy.sims);
    }
}
