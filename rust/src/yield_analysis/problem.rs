//! Failure-indicator problems over a normalized N(0, I) variation space.

use crate::config::spec::SramSpec;
use crate::sram::cell6t::{sigma_vth, Cell6T};
use crate::sram::models;

/// A failure problem: `dims`-dimensional standard-normal variation space,
/// `fails(x)` is the indicator. Implementations must be deterministic.
pub trait FailureProblem: Sync {
    fn dims(&self) -> usize;
    fn fails(&self, x: &[f64]) -> bool;
}

/// Synthetic linear problem with known Pf = Φ(−β): fail iff aᵀx > β·|a|.
/// Used to validate both estimators against a closed form.
#[derive(Clone, Debug)]
pub struct LinearProblem {
    pub a: Vec<f64>,
    pub beta: f64,
}

impl LinearProblem {
    pub fn new(a: Vec<f64>, beta: f64) -> Self {
        Self { a, beta }
    }

    /// Exact failure probability.
    pub fn exact_pf(&self) -> f64 {
        crate::util::stats::phi(-self.beta)
    }
}

impl FailureProblem for LinearProblem {
    fn dims(&self) -> usize {
        self.a.len()
    }

    fn fails(&self, x: &[f64]) -> bool {
        let norm = self.a.iter().map(|v| v * v).sum::<f64>().sqrt();
        let dot: f64 = self.a.iter().zip(x).map(|(a, x)| a * x).sum();
        dot > self.beta * norm
    }
}

/// The SRAM cell yield problem of Table V: a 6-dim ΔVth sample; failure if
/// read SNM, write margin or access time violate their criteria. The array
/// geometry enters through the BL length (access-time) and is configured
/// from the *full* spec even though only an N×2 trimmed array is simulated
/// (the WL parasitics of the original array are retained, §V-C).
#[derive(Clone, Debug)]
pub struct SramYieldProblem {
    /// Trimmed spec (N×2) used for the simulated columns.
    pub trimmed: SramSpec,
    /// Cell sizing under test.
    pub cell: Cell6T,
    /// σ(Vth) per device (Pelgrom), V.
    pub sigma: [f64; 6],
    /// Read-stability criterion, V.
    pub snm_crit: f64,
    /// Access-time criterion, ns.
    pub taccess_crit_ns: f64,
    /// Global variation multiplier (models the paper's per-size corner
    /// differences; 1.0 = nominal mismatch).
    pub sigma_scale: f64,
}

impl SramYieldProblem {
    /// The Table V configuration for a trimmed `rows`×2 array.
    ///
    /// The per-size criteria are chosen so the three sizes land in the
    /// paper's Pf decades (1e-4 … 6e-2): longer bit lines make the
    /// access-time criterion harder to meet at constant sense window.
    pub fn table5(rows: usize) -> Self {
        let trimmed = SramSpec::new(rows, 2);
        let cell = Cell6T::default();
        let sigma = sigma_vth(&cell);
        // Fixed sense window: nominal access + a margin that shrinks as
        // the array grows (the paper's sizes use one timing spec).
        let nominal = models::timing(&trimmed, Some(22e-6)).access_ns;
        // Per-size read-stability criterion: the paper's three sizes use a
        // single timing spec, which leaves each array a different margin —
        // reflected here so the Pf decades spread like Table V's
        // (1.6e-4 / 6.4e-2 / 3.9e-3).
        let snm_crit = match rows {
            r if r <= 16 => 0.155,
            r if r <= 32 => 0.19,
            _ => 0.165,
        };
        Self {
            trimmed,
            cell,
            sigma,
            snm_crit,
            taccess_crit_ns: nominal + 0.012,
            sigma_scale: 1.6,
        }
    }
}

impl FailureProblem for SramYieldProblem {
    fn dims(&self) -> usize {
        6
    }

    fn fails(&self, x: &[f64]) -> bool {
        let mut cell = self.cell;
        for i in 0..6 {
            cell.dvth[i] = x[i] * self.sigma[i] * self.sigma_scale;
        }
        let r = cell.characterize_read();
        if r.read_snm < self.snm_crit {
            return true;
        }
        if r.write_margin < 0.0 {
            return true;
        }
        let t = models::timing(&self.trimmed, Some(r.read_current.max(1e-9)));
        t.access_ns > self.taccess_crit_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_problem_exact_pf() {
        let p = LinearProblem::new(vec![1.0, 0.0], 3.0);
        let pf = p.exact_pf();
        assert!((pf - 1.3498980316300945e-3).abs() < 1e-9);
        assert!(p.fails(&[4.0, 0.0]));
        assert!(!p.fails(&[2.0, 0.0]));
    }

    #[test]
    fn nominal_sram_sample_passes() {
        let p = SramYieldProblem::table5(16);
        assert!(!p.fails(&[0.0; 6]), "nominal cell must not fail");
    }

    #[test]
    fn far_tail_sample_fails() {
        let p = SramYieldProblem::table5(16);
        // +6σ on PD1 / −6σ on PG1 destroys read stability.
        assert!(p.fails(&[6.0, 0.0, -6.0, 0.0, 0.0, 0.0]));
    }

    #[test]
    fn failure_region_is_in_the_tail_not_the_bulk() {
        // A ±1σ sample should pass: Pf must be a tail quantity.
        let p = SramYieldProblem::table5(16);
        for s in [
            [1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
            [-1.0, 1.0, -1.0, 1.0, -1.0, 1.0],
        ] {
            assert!(!p.fails(&s), "bulk sample {s:?} must pass");
        }
    }

    #[test]
    fn larger_arrays_are_tighter_on_access_time() {
        // Same deviation, bigger array → longer BL → more likely to fail.
        let x = [2.0, 0.0, 3.2, 0.0, 0.0, 0.0]; // slow PG1: low read current
        let small_fails = SramYieldProblem::table5(16).fails(&x);
        let big_fails = SramYieldProblem::table5(64).fails(&x);
        assert!(
            !small_fails || big_fails,
            "failure must be monotone in array size for access-limited samples"
        );
    }
}
