//! Model execution runtimes and the serving [`Backend`] abstraction.
//!
//! * [`client`] / [`artifacts`] — the PJRT path: loads the HLO-text
//!   artifacts produced by the Python build path
//!   (`python/compile/aot.py`) and executes them on the CPU PJRT client.
//!   This is the only module that touches the `xla` crate; Python is
//!   never on the request path (the artifacts are ahead-of-time
//!   compiled). Interchange is HLO *text*, not serialized protos — jax
//!   ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see
//!   /opt/xla-example/README.md).
//! * [`backend`] — the [`Backend`] trait the coordinator's batcher
//!   workers execute through, with a PJRT implementation and a
//!   pure-Rust [`NativeBackend`] (batched blocked LUT-GEMM) that needs
//!   no artifacts at all. See the module docs for the dispatch rules and
//!   the batching invariants every backend must uphold.

pub mod client;
pub mod artifacts;
pub mod backend;
pub mod fault;

pub use artifacts::ArtifactStore;
pub use backend::{
    fixture_logits, Backend, BackendChoice, BackendFactory, FixtureBackend, FixtureFactory,
    NativeBackend, NativeFactory, PjrtBackend, PjrtFactory, ServingWorkload,
};
pub use fault::{Fault, FaultPlan, LatencySpike, PanicStorm, SlowShard, TransientBursts};
pub use client::{CompiledModel, Runtime};
