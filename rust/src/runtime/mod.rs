//! PJRT runtime: loads the HLO-text artifacts produced by the Python
//! build path (`python/compile/aot.py`) and executes them on the CPU PJRT
//! client. This is the only module that touches the `xla` crate; Python is
//! never on the request path (the artifacts are ahead-of-time compiled).
//!
//! Interchange is HLO *text*, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod artifacts;

pub use artifacts::ArtifactStore;
pub use client::{CompiledModel, Runtime};
