//! Thin wrapper around the `xla` crate: CPU PJRT client + compiled
//! executables with typed input/output helpers.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// The PJRT CPU runtime.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<CompiledModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledModel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_default(),
        })
    }
}

impl CompiledModel {
    /// Execute with literal inputs; expects a 1-tuple result (jax lowering
    /// with `return_tuple=True`) and returns the contained literal.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("PJRT execute")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("device→host transfer")?;
        lit.to_tuple1().context("unwrapping 1-tuple result")
    }

    /// Execute and decode an f32 output of known element count.
    pub fn run_f32(&self, inputs: &[xla::Literal], expect_len: usize) -> Result<Vec<f32>> {
        let lit = self.run(inputs)?;
        let v = lit.to_vec::<f32>().context("decoding f32 output")?;
        if v.len() != expect_len {
            bail!("output length {} != expected {}", v.len(), expect_len);
        }
        Ok(v)
    }
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping i32 literal")
}

/// Build the weight-operand literal list from the artifact arrays
/// ([w1, b1, …] — i32 weight matrices, f32 biases).
pub fn weight_literals(weights: &[crate::util::npy::NpyArray]) -> Result<Vec<xla::Literal>> {
    weights
        .iter()
        .map(|arr| match arr.dtype {
            crate::util::npy::DType::I32 => literal_i32(&arr.shape, &arr.as_i32()?),
            crate::util::npy::DType::F32 => literal_f32(&arr.shape, &arr.as_f32()?),
            other => bail!("unsupported weight dtype {other:?}"),
        })
        .collect()
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping f32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise the real PJRT client; they are kept small.

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn literal_shapes() {
        let l = literal_i32(&[2, 3], &[1, 2, 3, 4, 5, 6]).unwrap();
        let back = l.to_vec::<i32>().unwrap();
        assert_eq!(back, vec![1, 2, 3, 4, 5, 6]);
    }
}
