//! Seeded, deterministic fault plans for the fixture backend.
//!
//! The byte-keyed faults on [`super::FixtureBackend`] place a single
//! error or panic at an exact request; chaos testing needs *temporal*
//! fault shapes — bursts, storms, skew — that unfold over a call
//! sequence. A [`FaultPlan`] describes those shapes as pure arithmetic
//! over `(shard, variant, call_index)`, so a plan plus a seed replays
//! the identical fault timeline on every run: the chaos suite in
//! `rust/tests/chaos.rs` and the `serve --chaos` smoke both assert
//! against deliveries produced under a known schedule.
//!
//! Call indices are tracked per shard×variant by the factory and
//! survive executor respawns, so a panic storm is a bounded window of
//! *calls*, not an infinite loop: the respawned backend resumes the
//! sequence where its predecessor died.

use std::time::Duration;

/// What a single `infer_batch` call should do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    None,
    /// Return `Err` (the `ExecuteFailed` / retry path).
    Error,
    /// Panic the executor (the respawn / `Health` path).
    Panic,
}

/// Transient-error bursts: `len` consecutive failing calls starting at
/// `start`, repeating every `period` calls (`period == 0` = one-shot).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransientBursts {
    pub start: u64,
    pub len: u64,
    pub period: u64,
}

/// Latency spikes: roughly one call in `every` sleeps `delay_us` before
/// answering, chosen by a seeded hash so spikes decorrelate across
/// shards and variants.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySpike {
    pub every: u64,
    pub delay_us: u64,
}

/// Panic storm: calls `[start, start + panics)` panic the executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct PanicStorm {
    pub start: u64,
    pub panics: u64,
}

/// One-slow-shard skew: every call on `shard` sleeps `delay_us`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SlowShard {
    pub shard: usize,
    pub delay_us: u64,
}

/// A deterministic fault schedule over `(shard, variant, call)`.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// When set, injected errors/panics hit only this variant (delays
    /// still apply everywhere) — lets a chaos scenario fault the cheap
    /// variant while the exact fallback stays healthy.
    pub variant: Option<String>,
    pub transient: Option<TransientBursts>,
    pub latency: Option<LatencySpike>,
    pub panic_storm: Option<PanicStorm>,
    pub slow_shard: Option<SlowShard>,
    /// Uniform per-call service time (µs), for load-shaping scenarios.
    pub exec_delay_us: u64,
}

impl FaultPlan {
    /// The moderate preset behind `openacm serve --chaos SEED`: periodic
    /// transient bursts, a short panic storm, occasional latency spikes,
    /// and a mildly slow shard 0 — all recoverable with a few retries
    /// and a small respawn budget.
    pub fn chaos_default(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            variant: None,
            transient: Some(TransientBursts {
                start: 10,
                len: 2,
                period: 24,
            }),
            latency: Some(LatencySpike {
                every: 32,
                delay_us: 400,
            }),
            panic_storm: Some(PanicStorm {
                start: 17,
                panics: 1,
            }),
            slow_shard: Some(SlowShard {
                shard: 0,
                delay_us: 150,
            }),
            exec_delay_us: 0,
        }
    }

    /// Decide what call number `call` on `shard`/`variant` does:
    /// returns the fault (panic beats error) and the pre-answer delay
    /// in µs. Pure — same inputs, same answer, on every run.
    pub fn decide(&self, shard: usize, variant: &str, call: u64) -> (Fault, u64) {
        let mut delay = self.exec_delay_us;
        if let Some(s) = self.slow_shard {
            if s.shard == shard {
                delay += s.delay_us;
            }
        }
        if let Some(l) = self.latency {
            if l.every > 0 && mix(self.seed, shard as u64, hash_str(variant), call) % l.every == 0
            {
                delay += l.delay_us;
            }
        }
        let scoped = match &self.variant {
            Some(v) => v == variant,
            None => true,
        };
        if scoped {
            if let Some(p) = self.panic_storm {
                if p.panics > 0 && call >= p.start && call < p.start + p.panics {
                    return (Fault::Panic, delay);
                }
            }
            if let Some(t) = self.transient {
                let in_burst = t.len > 0
                    && call >= t.start
                    && if t.period == 0 {
                        call < t.start + t.len
                    } else {
                        (call - t.start) % t.period < t.len
                    };
                if in_burst {
                    return (Fault::Error, delay);
                }
            }
        }
        (Fault::None, delay)
    }

    /// The delay for `decide` as a [`Duration`], for sleep call sites.
    pub fn delay_of(us: u64) -> Duration {
        Duration::from_micros(us)
    }
}

fn hash_str(s: &str) -> u64 {
    s.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    })
}

/// splitmix64-style mixer over the plan seed and call coordinates.
fn mix(seed: u64, a: u64, b: u64, c: u64) -> u64 {
    let mut z = seed
        .wrapping_add(a.wrapping_mul(0x9e37_79b9_7f4a_7c15))
        .wrapping_add(b.rotate_left(23))
        .wrapping_add(c.wrapping_mul(0xbf58_476d_1ce4_e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shot_burst_covers_exactly_its_window() {
        let plan = FaultPlan {
            transient: Some(TransientBursts {
                start: 5,
                len: 3,
                period: 0,
            }),
            ..FaultPlan::default()
        };
        for call in 0..20 {
            let (fault, _) = plan.decide(0, "exact", call);
            let expect = if (5..8).contains(&call) {
                Fault::Error
            } else {
                Fault::None
            };
            assert_eq!(fault, expect, "call {call}");
        }
    }

    #[test]
    fn periodic_bursts_repeat_and_panic_wins_over_error() {
        let plan = FaultPlan {
            transient: Some(TransientBursts {
                start: 0,
                len: 2,
                period: 8,
            }),
            panic_storm: Some(PanicStorm {
                start: 8,
                panics: 1,
            }),
            ..FaultPlan::default()
        };
        assert_eq!(plan.decide(0, "v", 0).0, Fault::Error);
        assert_eq!(plan.decide(0, "v", 1).0, Fault::Error);
        assert_eq!(plan.decide(0, "v", 2).0, Fault::None);
        // Call 8 is both burst-start and storm-start: panic wins.
        assert_eq!(plan.decide(0, "v", 8).0, Fault::Panic);
        assert_eq!(plan.decide(0, "v", 9).0, Fault::Error);
        assert_eq!(plan.decide(0, "v", 16).0, Fault::Error);
    }

    #[test]
    fn variant_scope_gates_faults_but_not_delays() {
        let plan = FaultPlan {
            variant: Some("cheap".to_string()),
            transient: Some(TransientBursts {
                start: 0,
                len: 100,
                period: 0,
            }),
            slow_shard: Some(SlowShard {
                shard: 1,
                delay_us: 50,
            }),
            ..FaultPlan::default()
        };
        assert_eq!(plan.decide(0, "cheap", 3).0, Fault::Error);
        assert_eq!(plan.decide(0, "exact", 3).0, Fault::None);
        // The slow-shard delay applies to every variant.
        assert_eq!(plan.decide(1, "exact", 3).1, 50);
        assert_eq!(plan.decide(0, "exact", 3).1, 0);
    }

    #[test]
    fn decide_is_deterministic_across_replays() {
        let plan = FaultPlan::chaos_default(42);
        for call in 0..200 {
            for shard in 0..2 {
                assert_eq!(
                    plan.decide(shard, "appro42", call),
                    plan.decide(shard, "appro42", call)
                );
            }
        }
    }

    #[test]
    fn latency_spikes_hit_roughly_one_in_every() {
        let plan = FaultPlan {
            seed: 7,
            latency: Some(LatencySpike {
                every: 16,
                delay_us: 100,
            }),
            ..FaultPlan::default()
        };
        let spikes = (0..1600)
            .filter(|&c| plan.decide(0, "exact", c).1 > 0)
            .count();
        assert!(
            (40..=220).contains(&spikes),
            "expected ~100 spikes in 1600 calls, got {spikes}"
        );
    }
}
