//! Artifact store: locates and loads everything `python/compile/aot.py`
//! emits — the HLO text graph, the per-family multiplier LUTs, the
//! quantized weights and the evaluation dataset.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::npy;

/// Loaded artifact bundle.
#[derive(Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    /// family name → int8 LUT (65536 i32 entries).
    pub luts: BTreeMap<String, Vec<i32>>,
    /// test images, N × 256 u8 (16×16 flattened).
    pub images: Vec<u8>,
    pub n_images: usize,
    /// labels, N.
    pub labels: Vec<usize>,
    /// The model HLO path (batch forward).
    pub model_hlo: PathBuf,
    /// Batch size the graph was lowered with.
    pub batch: usize,
    /// Weight operands in graph order [w1, b1, w2, b2, w3, b3, w4, b4]
    /// (weights i32 arrays of int8 values, biases f32). The graph takes
    /// them as runtime operands — see python/compile/model.py for why.
    pub weights: Vec<npy::NpyArray>,
}

impl ArtifactStore {
    /// Default artifacts directory (next to the repo root or overridden).
    pub fn default_dir() -> PathBuf {
        std::env::var("OPENACM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn exists(dir: &Path) -> bool {
        dir.join("model.hlo.txt").exists()
    }

    /// Load everything. Errors carry enough context to tell the user to
    /// run `make artifacts`.
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        if !Self::exists(dir) {
            bail!(
                "artifacts not found in {} — run `make artifacts` first",
                dir.display()
            );
        }
        let luts_dir = dir.join("luts");
        let mut luts = BTreeMap::new();
        for (stem, arr) in npy::read_dir(&luts_dir)
            .with_context(|| format!("reading {}", luts_dir.display()))?
        {
            let name = stem.trim_start_matches("lut_").to_string();
            let v = arr.as_i32()?;
            if v.len() != 65536 {
                bail!("lut {name} has {} entries, want 65536", v.len());
            }
            luts.insert(name, v);
        }
        if luts.is_empty() {
            bail!("no LUTs in {}", luts_dir.display());
        }
        let images_arr = npy::read(&dir.join("dataset/test_images.npy"))?;
        let images = images_arr.as_u8()?;
        let n_images = images_arr.shape[0];
        let labels: Vec<usize> = npy::read(&dir.join("dataset/test_labels.npy"))?
            .as_i64()?
            .iter()
            .map(|&l| l as usize)
            .collect();
        if labels.len() != n_images {
            bail!("labels {} != images {}", labels.len(), n_images);
        }
        // Batch size is recorded in manifest.txt as `batch=N`.
        let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap_or_default();
        let batch = manifest
            .lines()
            .find_map(|l| l.strip_prefix("batch=").and_then(|v| v.parse().ok()))
            .unwrap_or(32);
        // Weight operands in graph order.
        let wdir = dir.join("weights");
        let mut weights = Vec::new();
        for layer in ["conv1", "conv2", "fc1", "fc2"] {
            weights.push(npy::read(&wdir.join(format!("{layer}_q.npy")))?);
            weights.push(npy::read(&wdir.join(format!("{layer}_b.npy")))?);
        }
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            luts,
            images,
            n_images,
            labels,
            model_hlo: dir.join("model.hlo.txt"),
            weights,
            batch,
        })
    }

    /// One image as a 256-byte slice.
    pub fn image(&self, idx: usize) -> &[u8] {
        &self.images[idx * 256..(idx + 1) * 256]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_gives_actionable_error() {
        let e = ArtifactStore::load(Path::new("/nonexistent/path")).unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("OPENACM_ARTIFACTS", "/tmp/custom_artifacts");
        assert_eq!(
            ArtifactStore::default_dir(),
            PathBuf::from("/tmp/custom_artifacts")
        );
        std::env::remove_var("OPENACM_ARTIFACTS");
    }
}
