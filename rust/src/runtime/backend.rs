//! Execution backends for the serving coordinator.
//!
//! The coordinator's batcher workers are generic over [`Backend`]: a
//! classify-a-batch engine. Two implementations ship:
//!
//! * [`PjrtBackend`] — the AOT-compiled JAX graph through the PJRT
//!   runtime ([`super::CompiledModel`]); requires on-disk artifacts from
//!   `make artifacts` and a real `xla` crate behind [`super::Runtime`].
//! * [`NativeBackend`] — the pure-Rust batched quantized CNN
//!   ([`QuantCnn::forward_batch`] over the blocked LUT-GEMM kernel);
//!   needs **no artifacts and no PJRT**, so the full serving stack
//!   (admission → batcher → execute → respond) runs anywhere the crate
//!   compiles.
//!
//! ## Batching invariants (every backend must uphold)
//!
//! 1. `infer_batch` accepts `1..=max_batch()` images of exactly 256 bytes
//!    and returns exactly one 10-logit row per input, in input order.
//! 2. A request's logits are independent of its batchmates: padding a
//!    partial batch must never leak into real rows (the PJRT path pads
//!    with zero images and discards the padded rows; the native path has
//!    no padding at all).
//! 3. Determinism per backend: the native path is bit-identical to the
//!    scalar [`QuantCnn::forward`] reference for any batch size and
//!    thread count; the PJRT path is numerically equal to it within fp
//!    tolerance (`rust/tests/serving.rs::pjrt_and_native_forward_agree`).
//!
//! ## Dispatch rules
//!
//! Workers each own one backend instance — PJRT executables are not
//! shareable across threads, and the native path keeps per-worker scratch
//! — so the server is handed a [`BackendFactory`] and calls
//! [`BackendFactory::create`] once per variant worker, on the worker
//! thread. `openacm serve --backend auto` (the default) picks PJRT when
//! artifacts exist and the native backend otherwise; `--backend pjrt` /
//! `--backend native` force the choice ([`BackendChoice`]).

use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::artifacts::ArtifactStore;
use super::client;
use super::fault::{Fault, FaultPlan};
use crate::compile::plan::{CompiledPlan, PlanLuts};
use crate::mult::behavioral::{int8_lut, paper_families};
use crate::nn::eval::argmax;
use crate::nn::model::{synthetic_images, QuantCnn, LAYER_NAMES};
use crate::util::npy::NpyArray;

/// Number of logits per image (the 10-class quantized CNN).
pub const LOGITS: usize = 10;
/// Image payload size in bytes (16×16 grayscale).
pub const IMAGE_BYTES: usize = 256;

/// A batch-classification engine owned by one batcher worker.
pub trait Backend: Send {
    /// Short label for logs and metrics ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Largest batch one `infer_batch` call accepts.
    fn max_batch(&self) -> usize;

    /// Classify `images` (each 256 bytes); returns one 10-logit row per
    /// image, in input order.
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<f32>>>;

    /// Non-fatal conditions the backend wants surfaced (boot banner,
    /// tests). The native backend reports layers whose LUT exceeds the
    /// blocked GEMM's i32 partial-sum bound and therefore runs on the
    /// i64-widened scalar strip
    /// ([`crate::nn::quant::lut_exceeds_blocked_bound`]) — correct but
    /// slower, and worth knowing about since no real multiplier LUT
    /// triggers it.
    fn warnings(&self) -> &[String] {
        &[]
    }
}

/// Per-variant constructor for [`Backend`] instances. Shared by the
/// server handle and every worker thread.
pub trait BackendFactory: Send + Sync {
    /// Backend label, e.g. for the boot banner.
    fn backend_name(&self) -> &'static str;

    /// The multiplier variants this factory can serve (route keys).
    fn variants(&self) -> Vec<String>;

    /// Upper bound on any worker's batch (the server clamps its batching
    /// policy to this).
    fn max_batch(&self) -> usize;

    /// Build the backend for one variant. Called on the worker thread.
    fn create(&self, variant: &str) -> Result<Box<dyn Backend>>;

    /// Build the backend for one variant on a specific shard. The
    /// sharded pipeline (including executor respawns) calls this;
    /// backends that key deterministic behavior by shard — the fixture
    /// fault injector — override it, everything else ignores the shard.
    fn create_for_shard(&self, _shard: usize, variant: &str) -> Result<Box<dyn Backend>> {
        self.create(variant)
    }
}

/// Which backend `openacm serve` / the e2e example should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// PJRT when artifacts exist, native otherwise.
    Auto,
    Native,
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        match s {
            "auto" => Ok(BackendChoice::Auto),
            "native" => Ok(BackendChoice::Native),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Artifact-free backend: the batched Rust-native quantized CNN. Every
/// variant executes through per-layer LUTs ([`PlanLuts`]): uniform
/// variants share one table across all four layers, compiled-plan
/// variants dispatch each layer through its own — the same code path
/// either way ([`QuantCnn::forward_batch_hetero`]).
pub struct NativeBackend {
    cnn: Arc<QuantCnn>,
    luts: PlanLuts,
    threads: usize,
    max_batch: usize,
    /// One entry per layer whose LUT fails the blocked kernel's i32
    /// partial-sum bound (see [`Backend::warnings`]). Empty for every
    /// real multiplier family.
    warnings: Vec<String>,
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn warnings(&self) -> &[String] {
        &self.warnings
    }

    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<f32>>> {
        if images.len() > self.max_batch {
            bail!(
                "batch of {} exceeds native backend capacity {}",
                images.len(),
                self.max_batch
            );
        }
        for (i, img) in images.iter().enumerate() {
            if img.len() != IMAGE_BYTES {
                bail!("image {i} has {} bytes, want {IMAGE_BYTES}", img.len());
            }
        }
        Ok(self
            .cnn
            .forward_batch_hetero(&self.luts.layer_luts(), images, self.threads))
    }
}

/// Builds [`NativeBackend`]s: one shared quantized model + one LUT per
/// uniform variant, plus any number of compiled heterogeneous plans
/// registered via [`NativeFactory::add_plan`].
pub struct NativeFactory {
    cnn: Arc<QuantCnn>,
    luts: BTreeMap<String, Arc<Vec<i32>>>,
    plans: BTreeMap<String, PlanLuts>,
    max_batch: usize,
    threads: usize,
}

impl NativeFactory {
    /// From explicit parts. `threads` is the intra-batch GEMM parallelism
    /// *per worker* (1 = serial, deterministic output either way).
    pub fn new(
        cnn: QuantCnn,
        luts: BTreeMap<String, Vec<i32>>,
        max_batch: usize,
        threads: usize,
    ) -> NativeFactory {
        NativeFactory {
            cnn: Arc::new(cnn),
            luts: luts.into_iter().map(|(k, v)| (k, Arc::new(v))).collect(),
            plans: BTreeMap::new(),
            max_batch: max_batch.max(1),
            threads: threads.max(1),
        }
    }

    /// Register a compiled heterogeneous plan as a serving variant: the
    /// variant's workers dispatch each layer through the plan's own LUT.
    /// A plan shadows a uniform variant of the same name.
    pub fn add_plan(&mut self, variant: &str, plan: &CompiledPlan) {
        self.plans.insert(variant.to_string(), plan.build_luts());
    }

    /// The per-layer LUTs behind a plan variant (for reference checks).
    pub fn plan_luts(&self, variant: &str) -> Option<&PlanLuts> {
        self.plans.get(variant)
    }

    /// Real weights + real LUTs from the AOT artifact bundle, executed
    /// natively (no PJRT anywhere).
    pub fn from_artifacts(
        store: &ArtifactStore,
        max_batch: usize,
        threads: usize,
    ) -> Result<NativeFactory> {
        let cnn = QuantCnn::load(&store.dir).context("loading quantized weights")?;
        let luts = store
            .luts
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(NativeFactory::new(cnn, luts, max_batch, threads))
    }

    /// Fully artifact-free: behavioral LUTs for the four paper families
    /// computed in-process, around the given model (typically
    /// [`QuantCnn::random`]).
    pub fn paper_default(cnn: QuantCnn, max_batch: usize, threads: usize) -> NativeFactory {
        let luts = paper_families()
            .into_iter()
            .map(|(name, family)| (name, int8_lut(&family)))
            .collect();
        NativeFactory::new(cnn, luts, max_batch, threads)
    }

    /// The LUT behind one variant (for reference checks in tests).
    pub fn lut(&self, variant: &str) -> Option<&Arc<Vec<i32>>> {
        self.luts.get(variant)
    }

    /// The shared model.
    pub fn model(&self) -> &Arc<QuantCnn> {
        &self.cnn
    }
}

impl BackendFactory for NativeFactory {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn variants(&self) -> Vec<String> {
        self.plans
            .keys()
            .chain(self.luts.keys())
            .cloned()
            .collect::<BTreeSet<String>>()
            .into_iter()
            .collect()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn create(&self, variant: &str) -> Result<Box<dyn Backend>> {
        let luts = match self.plans.get(variant) {
            Some(plan) => plan.clone(),
            None => {
                let lut = self
                    .luts
                    .get(variant)
                    .with_context(|| format!("no LUT for variant {variant:?}"))?;
                PlanLuts::uniform(Arc::clone(lut))
            }
        };
        // Degenerate-LUT sweep: any layer outside the blocked kernel's
        // i32 partial-sum bound still infers bit-exactly (the kernel
        // falls back to an i64-widened scalar strip) but deserves a
        // loud note — no real multiplier family comes near the bound.
        let warnings: Vec<String> = LAYER_NAMES
            .iter()
            .zip(luts.layers.iter())
            .filter(|(_, lut)| crate::nn::quant::lut_exceeds_blocked_bound(lut))
            .map(|(layer, _)| {
                format!(
                    "variant {variant:?} layer {layer}: LUT entries exceed the blocked \
                     GEMM's i32 partial-sum bound; inference uses the i64-widened \
                     scalar fallback (bit-exact, but slower)"
                )
            })
            .collect();
        // Routed through the structured event log: the stderr mirror
        // preserves the historical `WARNING: …` line format, and the
        // JSONL trail makes the warning visible to `openacm obs tail`.
        for w in &warnings {
            crate::obs::warn("backend", w, &[("variant", variant.to_string())]);
        }
        Ok(Box::new(NativeBackend {
            cnn: Arc::clone(&self.cnn),
            luts,
            threads: self.threads,
            max_batch: self.max_batch,
            warnings,
        }))
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// One worker's compiled PJRT executable + resident operands.
pub struct PjrtBackend {
    /// Keeps the PJRT client alive for the executable's lifetime.
    _runtime: super::Runtime,
    model: super::CompiledModel,
    lut_lit: xla::Literal,
    weight_lits: Vec<xla::Literal>,
    /// The static batch the graph was lowered with (pad target).
    batch: usize,
}

impl PjrtBackend {
    /// Compile the graph and stage the LUT + weight operands.
    pub fn new(
        hlo: &std::path::Path,
        weights: &[NpyArray],
        lut: &[i32],
        batch: usize,
    ) -> Result<PjrtBackend> {
        let runtime = super::Runtime::cpu()?;
        let model = runtime.compile_hlo_text(hlo)?;
        let lut_lit = client::literal_i32(&[65536], lut)?;
        let weight_lits = client::weight_literals(weights)?;
        Ok(PjrtBackend {
            _runtime: runtime,
            model,
            lut_lit,
            weight_lits,
            batch: batch.max(1),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<f32>>> {
        let n = images.len();
        if n > self.batch {
            bail!("batch of {n} exceeds the graph's static batch {}", self.batch);
        }
        // Pad to the static batch with zero images; padded rows are
        // computed and discarded (invariant 2: no leakage into real rows).
        let b = self.batch;
        let mut px = vec![0i32; b * IMAGE_BYTES];
        for (j, img) in images.iter().enumerate() {
            if img.len() != IMAGE_BYTES {
                bail!("image {j} has {} bytes, want {IMAGE_BYTES}", img.len());
            }
            for (k, &p) in img.iter().enumerate() {
                px[j * IMAGE_BYTES + k] = p as i32;
            }
        }
        let img_lit = client::literal_i32(&[b, 16, 16], &px)?;
        let mut args = vec![img_lit, self.lut_lit.clone()];
        args.extend(self.weight_lits.iter().cloned());
        let out = self.model.run_f32(&args, b * LOGITS)?;
        Ok((0..n).map(|j| out[j * LOGITS..(j + 1) * LOGITS].to_vec()).collect())
    }
}

/// Builds [`PjrtBackend`]s from the artifact bundle; compilation happens
/// on each worker thread (executables are per-thread).
pub struct PjrtFactory {
    hlo: PathBuf,
    weights: Vec<NpyArray>,
    luts: BTreeMap<String, Arc<Vec<i32>>>,
    batch: usize,
}

impl PjrtFactory {
    pub fn from_artifacts(store: &ArtifactStore) -> PjrtFactory {
        PjrtFactory {
            hlo: store.model_hlo.clone(),
            weights: store.weights.clone(),
            luts: store
                .luts
                .iter()
                .map(|(k, v)| (k.clone(), Arc::new(v.clone())))
                .collect(),
            batch: store.batch,
        }
    }
}

impl BackendFactory for PjrtFactory {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn variants(&self) -> Vec<String> {
        self.luts.keys().cloned().collect()
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn create(&self, variant: &str) -> Result<Box<dyn Backend>> {
        let lut = self
            .luts
            .get(variant)
            .with_context(|| format!("no LUT for variant {variant:?}"))?;
        Ok(Box::new(PjrtBackend::new(
            &self.hlo,
            &self.weights,
            lut,
            self.batch,
        )?))
    }
}

// ---------------------------------------------------------------------------
// Fixture backend (tests + serving bench)
// ---------------------------------------------------------------------------

/// Reference logits of the fixture backend: a pure function of (variant,
/// image bytes), so a soak harness can bit-verify millions of deliveries
/// without precomputing anything — recompute and compare.
pub fn fixture_logits(variant: &str, image: &[u8]) -> Vec<f32> {
    let seed = crate::store::key::checksum64(image)
        ^ crate::store::key::checksum64(variant.as_bytes()).rotate_left(17);
    let mut rng = crate::util::rng::Pcg32::new(seed);
    (0..LOGITS).map(|_| rng.next_f64() as f32).collect()
}

/// Constant-time deterministic backend for pipeline tests and the serving
/// bench: logits come from [`fixture_logits`], so (1) deliveries are
/// bit-verifiable at million-request scale and (2) measured serving
/// overhead is the *pipeline's*, not the CNN's. Failure injection is
/// keyed on the first image byte, letting a workload generator place
/// backend errors and panics deterministically.
pub struct FixtureBackend {
    variant: String,
    max_batch: usize,
    fail_on_byte: Option<u8>,
    panic_on_byte: Option<u8>,
    fault: Option<FaultInjector>,
}

/// Per-backend handle into a [`FaultPlan`]: the call counter lives in
/// the factory keyed by shard×variant, so a respawned executor resumes
/// the fault timeline where its predecessor died instead of replaying
/// the same storm forever.
struct FaultInjector {
    plan: Arc<FaultPlan>,
    shard: usize,
    calls: Arc<AtomicU64>,
}

impl FaultInjector {
    /// Advance the call sequence; sleeps the scheduled delay and
    /// returns the fault this call must raise.
    fn tick(&self, variant: &str) -> Fault {
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        let (fault, delay_us) = self.plan.decide(self.shard, variant, call);
        if delay_us > 0 {
            std::thread::sleep(FaultPlan::delay_of(delay_us));
        }
        fault
    }
}

impl Backend for FixtureBackend {
    fn name(&self) -> &'static str {
        "fixture"
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<f32>>> {
        if images.len() > self.max_batch {
            bail!(
                "batch of {} exceeds fixture backend capacity {}",
                images.len(),
                self.max_batch
            );
        }
        if let Some(inj) = &self.fault {
            match inj.tick(&self.variant) {
                Fault::Panic => panic!(
                    "injected chaos panic (variant {}, shard {})",
                    self.variant, inj.shard
                ),
                Fault::Error => bail!(
                    "injected chaos failure (variant {}, shard {})",
                    self.variant,
                    inj.shard
                ),
                Fault::None => {}
            }
        }
        for (i, img) in images.iter().enumerate() {
            if img.len() != IMAGE_BYTES {
                bail!("image {i} has {} bytes, want {IMAGE_BYTES}", img.len());
            }
            if Some(img[0]) == self.panic_on_byte {
                panic!("injected fixture panic (variant {})", self.variant);
            }
            if Some(img[0]) == self.fail_on_byte {
                bail!("injected fixture failure (variant {})", self.variant);
            }
        }
        Ok(images
            .iter()
            .map(|img| fixture_logits(&self.variant, img))
            .collect())
    }
}

/// Builds [`FixtureBackend`]s for an arbitrary variant menu.
pub struct FixtureFactory {
    variants: Vec<String>,
    max_batch: usize,
    fail_on_byte: Option<u8>,
    panic_on_byte: Option<u8>,
    fault_plan: Option<Arc<FaultPlan>>,
    /// shard×variant → shared call counter, so respawned backends
    /// continue the fault timeline instead of restarting it.
    fault_calls: Mutex<HashMap<(usize, String), Arc<AtomicU64>>>,
}

impl FixtureFactory {
    pub fn new(variants: &[&str], max_batch: usize) -> FixtureFactory {
        FixtureFactory {
            variants: variants.iter().map(|v| v.to_string()).collect(),
            max_batch: max_batch.max(1),
            fail_on_byte: None,
            panic_on_byte: None,
            fault_plan: None,
            fault_calls: Mutex::new(HashMap::new()),
        }
    }

    /// Batches containing an image whose first byte equals `b` error out
    /// (the `ExecuteFailed` path).
    pub fn fail_on_byte(mut self, b: u8) -> FixtureFactory {
        self.fail_on_byte = Some(b);
        self
    }

    /// Batches containing an image whose first byte equals `b` panic the
    /// executor (the `WorkerPanicked` / health path).
    pub fn panic_on_byte(mut self, b: u8) -> FixtureFactory {
        self.panic_on_byte = Some(b);
        self
    }

    /// Drive every backend this factory builds from a seeded
    /// [`FaultPlan`] (in addition to any byte-keyed faults).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> FixtureFactory {
        self.fault_plan = Some(Arc::new(plan));
        self
    }

    fn build(&self, shard: usize, variant: &str) -> Result<Box<dyn Backend>> {
        if !self.variants.iter().any(|v| v == variant) {
            bail!("no fixture variant {variant:?}");
        }
        let fault = self.fault_plan.as_ref().map(|plan| FaultInjector {
            plan: Arc::clone(plan),
            shard,
            calls: Arc::clone(
                self.fault_calls
                    .lock()
                    .unwrap()
                    .entry((shard, variant.to_string()))
                    .or_default(),
            ),
        });
        Ok(Box::new(FixtureBackend {
            variant: variant.to_string(),
            max_batch: self.max_batch,
            fail_on_byte: self.fail_on_byte,
            panic_on_byte: self.panic_on_byte,
            fault,
        }))
    }
}

impl BackendFactory for FixtureFactory {
    fn backend_name(&self) -> &'static str {
        "fixture"
    }

    fn variants(&self) -> Vec<String> {
        self.variants.clone()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn create(&self, variant: &str) -> Result<Box<dyn Backend>> {
        self.build(0, variant)
    }

    fn create_for_shard(&self, shard: usize, variant: &str) -> Result<Box<dyn Backend>> {
        self.build(shard, variant)
    }
}

// ---------------------------------------------------------------------------
// Serving workloads + backend selection
// ---------------------------------------------------------------------------

/// The evaluation workload a serving demo drives requests from: either a
/// snapshot of the artifact dataset, or — with no artifacts anywhere —
/// deterministic synthetic images labeled by the *exact* variant of the
/// served model, so each approximate variant's "Top-1" reads as agreement
/// with exact-multiplier inference.
pub struct ServingWorkload {
    /// `n_images * 256` bytes, 16×16 each.
    pub images: Vec<u8>,
    pub n_images: usize,
    /// Ground-truth (artifact dataset) or exact-forward-argmax (synthetic)
    /// label per image.
    pub labels: Vec<usize>,
}

impl ServingWorkload {
    /// Snapshot the artifact dataset as a serving workload.
    pub fn from_store(store: &ArtifactStore) -> ServingWorkload {
        ServingWorkload {
            images: store.images.clone(),
            n_images: store.n_images,
            labels: store.labels.clone(),
        }
    }

    /// One image as a 256-byte slice.
    pub fn image(&self, idx: usize) -> &[u8] {
        &self.images[idx * IMAGE_BYTES..(idx + 1) * IMAGE_BYTES]
    }
}

/// Build the artifact-free native serving setup: a deterministic random
/// quantized CNN, behavioral LUTs for the paper families, and a labeled
/// synthetic workload (labels via the shared [`argmax`], the same one the
/// server applies to responses).
pub fn synthetic_serving_setup(
    n_images: usize,
    seed: u64,
    max_batch: usize,
    threads: usize,
) -> (NativeFactory, ServingWorkload) {
    let factory = NativeFactory::paper_default(QuantCnn::random(seed), max_batch, threads);
    let images = synthetic_images(n_images, seed ^ 0x5EED_1A6E);
    let exact = factory
        .lut("exact")
        .expect("paper families always include exact");
    let views: Vec<&[u8]> = images.chunks(IMAGE_BYTES).collect();
    let labels = factory
        .model()
        .forward_batch(exact, &views, threads)
        .iter()
        .map(|logits| argmax(logits))
        .collect();
    (
        factory,
        ServingWorkload {
            images,
            n_images,
            labels,
        },
    )
}

/// Resolve `--backend native|pjrt|auto` against what is on disk in `dir`
/// into a ready factory + the workload to drive it with — the one
/// dispatch-rule implementation shared by `openacm serve` and
/// `examples/e2e_serving.rs`. Prints a one-line notice when falling back
/// to the synthetic workload.
///
/// `threads` is the machine-wide parallelism budget: since the server
/// runs one batcher worker per variant and all variants serve
/// concurrently, each native worker gets `threads / variant-count`
/// intra-batch GEMM threads (min 1) instead of oversubscribing every
/// core per worker.
pub fn select_backend(
    choice: BackendChoice,
    dir: &Path,
    max_batch: usize,
    threads: usize,
    seed: u64,
) -> Result<(Arc<dyn BackendFactory>, ServingWorkload)> {
    select_backend_with_plan(choice, dir, max_batch, threads, seed, None)
}

/// [`select_backend`] that additionally registers a compiled
/// heterogeneous plan as a serving variant (`openacm serve --plan`).
/// Plans execute through per-layer LUT dispatch, which only the native
/// backend implements — combining `--plan` with a forced PJRT backend is
/// an error, and `auto` with a plan prefers native even when artifacts
/// exist.
pub fn select_backend_with_plan(
    choice: BackendChoice,
    dir: &Path,
    max_batch: usize,
    threads: usize,
    seed: u64,
    plan: Option<(&str, &CompiledPlan)>,
) -> Result<(Arc<dyn BackendFactory>, ServingWorkload)> {
    let have_artifacts = ArtifactStore::exists(dir);
    if plan.is_some() && choice == BackendChoice::Pjrt {
        bail!("compiled plans execute on the native backend; drop --backend pjrt or --plan");
    }
    // A plan forces the native path (per-layer LUT dispatch).
    let native = plan.is_some() || choice == BackendChoice::Native;
    match (choice, native, have_artifacts) {
        (BackendChoice::Pjrt, _, false) => bail!(
            "--backend pjrt needs artifacts in {} — run `make artifacts` \
             (or use --backend native)",
            dir.display()
        ),
        (_, false, true) => {
            let store = ArtifactStore::load(dir)?;
            let workload = ServingWorkload::from_store(&store);
            Ok((Arc::new(PjrtFactory::from_artifacts(&store)), workload))
        }
        (_, true, true) => {
            let store = ArtifactStore::load(dir)?;
            let workload = ServingWorkload::from_store(&store);
            let variants = store.luts.len() + usize::from(plan.is_some());
            let per_worker = (threads / variants.max(1)).max(1);
            let mut factory = NativeFactory::from_artifacts(&store, max_batch, per_worker)?;
            if let Some((name, plan)) = plan {
                warn_on_model_mismatch(plan, factory.model());
                factory.add_plan(name, plan);
            }
            Ok((Arc::new(factory), workload))
        }
        (_, _, false) => {
            println!(
                "no artifacts in {} — native backend on a synthetic workload \
                 (labels = exact-variant predictions)",
                dir.display()
            );
            // Paper-family variants (+ any plan) share the thread budget.
            let variants = paper_families().len() + usize::from(plan.is_some());
            let per_worker = (threads / variants.max(1)).max(1);
            let (mut factory, workload) =
                synthetic_serving_setup(256, seed, max_batch, per_worker);
            if let Some((name, plan)) = plan {
                warn_on_model_mismatch(plan, factory.model());
                factory.add_plan(name, plan);
            }
            Ok((Arc::new(factory), workload))
        }
    }
}

/// A plan's measured accuracy/energy claims only hold for the model it
/// was compiled against; serving it on a different model still executes
/// fine (the LUT assignment is model-independent), but the claims become
/// meaningless — say so loudly instead of silently reporting compile-time
/// numbers for the wrong model.
fn warn_on_model_mismatch(plan: &CompiledPlan, model: &QuantCnn) {
    if crate::compile::search::model_content_hash(model).0 != plan.model_hash {
        crate::obs::warn(
            "backend",
            &format!(
                "plan {:?} was compiled for a different model (hash mismatch) — \
                 its measured accuracy drop and energy estimates do not apply to the \
                 model being served; recompile with `openacm compile` against this model",
                plan.name
            ),
            &[("plan", plan.name.clone())],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("native").unwrap(), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(), BackendChoice::Pjrt);
        assert!(BackendChoice::parse("tpu").is_err());
    }

    #[test]
    fn native_factory_serves_requested_variants() {
        let mut luts = BTreeMap::new();
        luts.insert("exact".to_string(), vec![0i32; 65536]);
        let f = NativeFactory::new(QuantCnn::random(1), luts, 8, 1);
        assert_eq!(f.variants(), vec!["exact".to_string()]);
        assert_eq!(f.max_batch(), 8);
        let mut be = f.create("exact").unwrap();
        assert_eq!(be.name(), "native");
        assert_eq!(be.max_batch(), 8);
        assert!(f.create("nope").is_err());
        // All-zero LUT → every product 0 → logits are exactly the biases.
        let img = vec![0u8; IMAGE_BYTES];
        let rows = be.infer_batch(&[img.as_slice(), img.as_slice()]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), LOGITS);
        assert_eq!(rows[0], rows[1]);
    }

    #[test]
    fn native_backend_rejects_bad_shapes() {
        let mut luts = BTreeMap::new();
        luts.insert("exact".to_string(), vec![0i32; 65536]);
        let f = NativeFactory::new(QuantCnn::random(1), luts, 2, 1);
        let mut be = f.create("exact").unwrap();
        let img = vec![0u8; IMAGE_BYTES];
        let short = vec![0u8; 100];
        assert!(
            be.infer_batch(&[img.as_slice(), img.as_slice(), img.as_slice()])
                .is_err(),
            "over capacity"
        );
        assert!(be.infer_batch(&[short.as_slice()]).is_err(), "truncated image");
        assert!(be.infer_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn plan_variant_dispatches_per_layer_luts() {
        use crate::compile::plan::{LayerPlan, PLAN_VERSION};
        use crate::config::spec::MultFamily;
        use crate::nn::model::{layer_macs_per_image, LAYER_NAMES, N_LAYERS};

        let macs = layer_macs_per_image();
        let families = [
            MultFamily::Exact,
            MultFamily::Exact,
            MultFamily::Mitchell,
            MultFamily::Exact,
        ];
        let plan = CompiledPlan {
            name: "unit".into(),
            bits: 8,
            budget_drop: 0.01,
            model_hash: 1,
            calib_hash: 2,
            calib_n: 4,
            exact_top1: 1.0,
            plan_top1: 1.0,
            exact_energy_per_image_j: 1.0,
            plan_energy_per_image_j: 0.5,
            layers: (0..N_LAYERS)
                .map(|i| LayerPlan {
                    layer: LAYER_NAMES[i].to_string(),
                    family: families[i].clone(),
                    energy_per_op_j: 1e-12,
                    macs_per_image: macs[i],
                    solo_drop: 0.0,
                })
                .collect(),
        };
        assert_eq!(PLAN_VERSION, 1);

        let mut luts = BTreeMap::new();
        luts.insert("exact".to_string(), crate::mult::behavioral::int8_lut(&MultFamily::Exact));
        let mut f = NativeFactory::new(QuantCnn::random(6), luts, 8, 1);
        f.add_plan("plan", &plan);
        assert_eq!(
            f.variants(),
            vec!["exact".to_string(), "plan".to_string()]
        );

        // Served logits must bit-match a direct heterogeneous forward.
        let images = synthetic_images(3, 13);
        let views: Vec<&[u8]> = images.chunks(IMAGE_BYTES).collect();
        let mut be = f.create("plan").unwrap();
        let served = be.infer_batch(&views).unwrap();
        let plan_luts = plan.build_luts();
        let direct = f
            .model()
            .forward_batch_hetero(&plan_luts.layer_luts(), &views, 1);
        assert_eq!(served, direct);
        // And it must differ from the uniform exact variant (fc1 runs the
        // Mitchell LUT).
        let mut exact_be = f.create("exact").unwrap();
        assert_ne!(exact_be.infer_batch(&views).unwrap(), served);
    }

    #[test]
    fn degenerate_lut_variant_warns_and_stays_bit_exact() {
        // A LUT past the blocked kernel's i32 partial-sum bound: serving
        // must flag it once per layer and still match the scalar
        // per-image forward bit for bit (i64-widened fallback).
        let mut hostile = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                hostile[(((a as u8) as usize) << 8) | ((b as u8) as usize)] =
                    if (a ^ b) < 0 { i32::MIN + 1 } else { i32::MAX };
            }
        }
        let mut luts = BTreeMap::new();
        luts.insert("hostile".to_string(), hostile.clone());
        luts.insert("exact".to_string(), crate::mult::behavioral::int8_lut(
            &crate::config::spec::MultFamily::Exact,
        ));
        let f = NativeFactory::new(QuantCnn::random(3), luts, 8, 1);

        let clean = f.create("exact").unwrap();
        assert!(clean.warnings().is_empty(), "real LUTs must not warn");

        let mut be = f.create("hostile").unwrap();
        assert_eq!(
            be.warnings().len(),
            crate::nn::model::N_LAYERS,
            "uniform hostile LUT flags every layer"
        );
        assert!(be.warnings()[0].contains("i64-widened"));

        let images = synthetic_images(2, 7);
        let views: Vec<&[u8]> = images.chunks(IMAGE_BYTES).collect();
        let served = be.infer_batch(&views).unwrap();
        for (row, img) in served.iter().zip(&views) {
            assert_eq!(row, &f.model().forward(&hostile, img));
        }
    }

    #[test]
    fn fixture_backend_is_deterministic_and_injectable() {
        let f = FixtureFactory::new(&["a", "b"], 4);
        assert_eq!(f.variants(), vec!["a".to_string(), "b".to_string()]);
        assert!(f.create("nope").is_err());
        let mut be = f.create("a").unwrap();
        let img1 = vec![7u8; IMAGE_BYTES];
        let img2 = vec![9u8; IMAGE_BYTES];
        let rows = be.infer_batch(&[&img1, &img2]).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), LOGITS);
        // Bit-reproducible from the pure reference function, and
        // variant-dependent.
        assert_eq!(rows[0], fixture_logits("a", &img1));
        assert_eq!(rows[1], fixture_logits("a", &img2));
        assert_ne!(fixture_logits("a", &img1), fixture_logits("b", &img1));
        assert_ne!(rows[0], rows[1]);
        // Shape guards.
        let short = vec![0u8; 3];
        assert!(be.infer_batch(&[short.as_slice()]).is_err());
        assert!(be
            .infer_batch(&[&img1, &img1, &img1, &img1, &img1])
            .is_err());

        // Injected failure and panic, keyed on the first image byte.
        let f = FixtureFactory::new(&["a"], 4).fail_on_byte(0xEE).panic_on_byte(0xDD);
        let mut be = f.create("a").unwrap();
        let bad = vec![0xEEu8; IMAGE_BYTES];
        assert!(be.infer_batch(&[bad.as_slice()]).is_err());
        let boom = vec![0xDDu8; IMAGE_BYTES];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = be.infer_batch(&[boom.as_slice()]);
        }));
        assert!(r.is_err(), "panic byte must panic");
    }

    #[test]
    fn synthetic_workload_is_deterministic_and_labeled() {
        let (f1, w1) = synthetic_serving_setup(6, 42, 8, 1);
        let (_, w2) = synthetic_serving_setup(6, 42, 8, 2);
        assert_eq!(w1.images, w2.images);
        assert_eq!(w1.labels, w2.labels);
        assert_eq!(w1.n_images, 6);
        assert_eq!(w1.labels.len(), 6);
        assert!(w1.labels.iter().all(|&l| l < LOGITS));
        // Labels really are the exact variant's argmax.
        let mut be = f1.create("exact").unwrap();
        let rows = be.infer_batch(&[w1.image(3)]).unwrap();
        assert_eq!(argmax(&rows[0]), w1.labels[3]);
    }

    #[test]
    fn select_backend_dispatch_rules_without_artifacts() {
        let nowhere = Path::new("/nonexistent/openacm-artifacts");
        // pjrt without artifacts fails fast with an actionable message.
        let err = select_backend(BackendChoice::Pjrt, nowhere, 8, 1, 1).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
        // native and auto both fall back to the synthetic setup.
        for choice in [BackendChoice::Native, BackendChoice::Auto] {
            let (factory, workload) = select_backend(choice, nowhere, 8, 1, 1).unwrap();
            assert_eq!(factory.backend_name(), "native");
            assert_eq!(workload.n_images, 256);
            assert_eq!(factory.variants().len(), 4);
        }
    }
}
