//! FakeRAM2.0-style abstract-view emission (paper §III-D item 3):
//! a LEF abstract (footprint + pin geometry) and a LIB (timing/power) view
//! for black-box place-and-route integration, named and organized so the
//! macro drops into flows that already consume FakeRAM macros (e.g.
//! OpenROAD's tinyRocket `fakeram45_256x16`).

use crate::config::spec::SramSpec;
use crate::sram::models;

/// Macro cell name in FakeRAM convention: `fakeram45_<rows>x<bits>`.
pub fn macro_name(spec: &SramSpec) -> String {
    format!("fakeram45_{}x{}", spec.rows, spec.word_bits)
}

fn dims_um(spec: &SramSpec) -> (f64, f64) {
    // Near-square footprint with the model's total area.
    let a = models::area(spec).total_um2;
    let w = (a * 1.4).sqrt(); // slightly wide aspect, like FakeRAM
    let h = a / w;
    (round2(w), round2(h))
}

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

/// Emit the LEF abstract.
pub fn lef(spec: &SramSpec) -> String {
    let name = macro_name(spec);
    let (w, h) = dims_um(spec);
    let addr_bits = (usize::BITS - (spec.rows - 1).leading_zeros()) as usize;
    let mut pins = String::new();
    let mut pin = |pname: &str, dir: &str, y: f64| {
        pins.push_str(&format!(
            "  PIN {pname}\n    DIRECTION {dir} ;\n    USE SIGNAL ;\n    PORT\n      LAYER metal4 ;\n        RECT 0.000 {:.3} 0.190 {:.3} ;\n    END\n  END {pname}\n",
            y,
            y + 0.14
        ));
    };
    let mut y = 1.0;
    for i in 0..spec.word_bits {
        pin(&format!("rd_out[{i}]"), "OUTPUT", y);
        y += 0.5;
    }
    for i in 0..spec.word_bits {
        pin(&format!("wd_in[{i}]"), "INPUT", y);
        y += 0.5;
    }
    for i in 0..addr_bits {
        pin(&format!("addr_in[{i}]"), "INPUT", y);
        y += 0.5;
    }
    for p in ["we_in", "ce_in", "clk"] {
        pin(p, "INPUT", y);
        y += 0.5;
    }
    format!(
        "VERSION 5.7 ;\nBUSBITCHARS \"[]\" ;\nMACRO {name}\n  FOREIGN {name} 0 0 ;\n  SYMMETRY X Y R90 ;\n  SIZE {w:.3} BY {h:.3} ;\n  CLASS BLOCK ;\n{pins}  OBS\n    LAYER metal1 ;\n      RECT 0 0 {w:.3} {h:.3} ;\n    LAYER metal2 ;\n      RECT 0 0 {w:.3} {h:.3} ;\n    LAYER metal3 ;\n      RECT 0 0 {w:.3} {h:.3} ;\n  END\nEND {name}\n"
    )
}

/// Emit the LIB (Liberty) timing/power view, with values taken from the
/// characterization models (and therefore consistent with Table II).
pub fn lib(spec: &SramSpec, clock_mhz: f64) -> String {
    let name = macro_name(spec);
    let t = models::timing(spec, None);
    let p = models::power(spec, clock_mhz * 1e6);
    let access_ns = t.access_ns;
    let setup_ns = 0.05;
    let hold_ns = 0.05;
    let leakage_mw = p.leakage_w * 1e3;
    let addr_bits = (usize::BITS - (spec.rows - 1).leading_zeros()) as usize;
    format!(
        r#"library({name}) {{
  delay_model : table_lookup;
  time_unit : "1ns";
  voltage_unit : "1V";
  current_unit : "1mA";
  leakage_power_unit : "1mW";
  capacitive_load_unit(1, pf);
  nom_voltage : 1.1;
  nom_temperature : 25;
  cell({name}) {{
    area : {area:.2};
    is_macro_cell : true;
    cell_leakage_power : {leakage_mw:.6};
    pin(clk) {{ direction : input; clock : true; capacitance : 0.01; }}
    pin(we_in) {{ direction : input; capacitance : 0.005;
      timing() {{ related_pin : "clk"; timing_type : setup_rising;
        rise_constraint(scalar) {{ values("{setup_ns:.3}"); }}
        fall_constraint(scalar) {{ values("{setup_ns:.3}"); }} }}
      timing() {{ related_pin : "clk"; timing_type : hold_rising;
        rise_constraint(scalar) {{ values("{hold_ns:.3}"); }}
        fall_constraint(scalar) {{ values("{hold_ns:.3}"); }} }}
    }}
    bus(addr_in) {{ bus_type : addr_{addr_bits};
      direction : input; capacitance : 0.005; }}
    bus(wd_in) {{ bus_type : data_{bits};
      direction : input; capacitance : 0.005; }}
    bus(rd_out) {{ bus_type : data_{bits};
      direction : output;
      timing() {{ related_pin : "clk"; timing_type : rising_edge;
        cell_rise(scalar) {{ values("{access_ns:.3}"); }}
        rise_transition(scalar) {{ values("0.05"); }}
        cell_fall(scalar) {{ values("{access_ns:.3}"); }}
        fall_transition(scalar) {{ values("0.05"); }} }}
    }}
  }}
  type(addr_{addr_bits}) {{ base_type : array; data_type : bit;
    bit_width : {addr_bits}; bit_from : {addr_hi}; bit_to : 0; }}
  type(data_{bits}) {{ base_type : array; data_type : bit;
    bit_width : {bits}; bit_from : {bits_hi}; bit_to : 0; }}
}}
"#,
        name = name,
        area = models::area(spec).total_um2,
        leakage_mw = leakage_mw,
        setup_ns = setup_ns,
        hold_ns = hold_ns,
        access_ns = access_ns,
        addr_bits = addr_bits,
        addr_hi = addr_bits - 1,
        bits = spec.word_bits,
        bits_hi = spec.word_bits - 1,
    )
}

/// Verilog behavioral model (write-first synchronous RAM), FakeRAM style.
pub fn verilog(spec: &SramSpec) -> String {
    let name = macro_name(spec);
    let addr_bits = (usize::BITS - (spec.rows - 1).leading_zeros()) as usize;
    format!(
        r#"// FakeRAM2.0-style behavioral model generated by OpenACM.
module {name} (
    input  wire                     clk,
    input  wire                     ce_in,
    input  wire                     we_in,
    input  wire [{ah}:0]            addr_in,
    input  wire [{dh}:0]            wd_in,
    output reg  [{dh}:0]            rd_out
);
  reg [{dh}:0] mem [0:{rows_m1}];
  always @(posedge clk) begin
    if (ce_in) begin
      if (we_in) mem[addr_in] <= wd_in;
      rd_out <= mem[addr_in];
    end
  end
endmodule
"#,
        name = name,
        ah = addr_bits - 1,
        dh = spec.word_bits - 1,
        rows_m1 = spec.rows - 1,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SramSpec;

    #[test]
    fn names_follow_fakeram_convention() {
        assert_eq!(macro_name(&SramSpec::new(16, 8)), "fakeram45_16x8");
        assert_eq!(macro_name(&SramSpec::new(256, 16)), "fakeram45_256x16");
    }

    #[test]
    fn lef_contains_required_sections() {
        let s = lef(&SramSpec::new(32, 16));
        assert!(s.contains("MACRO fakeram45_32x16"));
        assert!(s.contains("CLASS BLOCK"));
        assert!(s.contains("PIN rd_out[15]"));
        assert!(s.contains("PIN addr_in[4]"));
        assert!(s.contains("SIZE"));
        assert!(s.contains("END fakeram45_32x16"));
    }

    #[test]
    fn lib_reports_model_access_time() {
        let spec = SramSpec::new(64, 32);
        let s = lib(&spec, 100.0);
        let t = models::timing(&spec, None).access_ns;
        assert!(s.contains(&format!("values(\"{t:.3}\")")));
        assert!(s.contains("is_macro_cell : true"));
    }

    #[test]
    fn verilog_module_shape() {
        let v = verilog(&SramSpec::new(16, 8));
        assert!(v.contains("module fakeram45_16x8"));
        assert!(v.contains("mem [0:15]"));
        assert!(v.contains("[7:0]"));
        assert!(v.contains("[3:0]            addr_in"));
    }

    #[test]
    fn lef_area_matches_model() {
        let spec = SramSpec::new(16, 8);
        let s = lef(&spec);
        // Extract SIZE W BY H and check W*H ≈ model area.
        let line = s.lines().find(|l| l.trim().starts_with("SIZE")).unwrap();
        let toks: Vec<&str> = line.split_whitespace().collect();
        let w: f64 = toks[1].parse().unwrap();
        let h: f64 = toks[3].parse().unwrap();
        let a = models::area(&spec).total_um2;
        assert!(((w * h) / a - 1.0).abs() < 0.02, "{} vs {}", w * h, a);
    }
}
