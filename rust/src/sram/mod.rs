//! SRAM macro compiler (paper §III-D) and its transistor-level
//! characterization — the substitution for Xyce SPICE + OpenYield
//! (DESIGN.md §3).
//!
//! * [`device`] — long-channel square-law MOSFET model (with a velocity-
//!   saturation correction) for the FreePDK45 45 nm node;
//! * [`cell6t`] — the 6T bit cell: DC operating-point solver (bisection on
//!   the node current balance), butterfly curves, read/write/hold SNM and
//!   read current, all under per-transistor Vth mismatch;
//! * [`macro_gen`] — banked/subarrayed array organization with hierarchical
//!   WL decoders, precharge, write drivers, column mux and sense amps, plus
//!   a functional read/write behavioral model;
//! * [`models`] — calibrated area / access-time / power models (the SRAM
//!   columns of Table II);
//! * [`fakeram`] — FakeRAM2.0-style LEF + LIB view emission for
//!   place-and-route black-box integration.

pub mod device;
pub mod cell6t;
pub mod macro_gen;
pub mod models;
pub mod fakeram;
pub mod sizing;

pub use cell6t::{Cell6T, CellCorners, SnmReport};
pub use macro_gen::SramMacro;
pub use models::{SramArea, SramPower, SramTiming};
pub use sizing::{optimize as optimize_sizing, SizingResult, SizingTargets};
