//! Calibrated SRAM area / timing / power models — the "SRAM" columns of
//! Table II and the access-time component of the system delay.
//!
//! Calibration (DESIGN.md §7): the paper reports SRAM areas of ≈7.0k /
//! 16.9k / 48.0k µm² for 16×8 / 32×16 / 64×32 macros and a system critical
//! delay of ≈5.2 ns at 100 MHz that is *SRAM-dominated* and almost
//! size-independent. The structural models below (bitcell + per-row +
//! per-column periphery + fixed control; decoder/WL/BL/SA delay chain) are
//! fitted to land in that envelope; each constant is documented.

use super::device::process;
use super::macro_gen::SramMacro;
use crate::config::spec::SramSpec;

/// Area result, µm².
#[derive(Clone, Copy, Debug, Default)]
pub struct SramArea {
    pub cell_array_um2: f64,
    pub periphery_um2: f64,
    pub total_um2: f64,
}

/// Timing result, ns.
#[derive(Clone, Copy, Debug, Default)]
pub struct SramTiming {
    pub decoder_ns: f64,
    pub wordline_ns: f64,
    pub bitline_ns: f64,
    pub sense_ns: f64,
    pub access_ns: f64,
}

/// Power result, W (at a given access rate).
#[derive(Clone, Copy, Debug, Default)]
pub struct SramPower {
    pub read_dynamic_w: f64,
    pub leakage_w: f64,
}

impl SramPower {
    pub fn total_w(&self) -> f64 {
        self.read_dynamic_w + self.leakage_w
    }
}

// --- area ---------------------------------------------------------------

/// Effective per-bitcell area including in-array routing, well taps,
/// redundancy and dummy rows (µm²). The *physical* FreePDK45 6T cell is
/// ≈1 µm²; small educational macros in the paper's Table II report
/// substantially larger effective area — this constant absorbs that
/// overhead so generated macros land in the paper's envelope.
const CELL_EFF_UM2: f64 = 15.0;
/// Per-row periphery (WL driver + row-decoder slice), µm².
const ROW_PERIPH_UM2: f64 = 50.0;
/// Per-physical-column periphery (precharge + write driver + SA + mux), µm².
const COL_PERIPH_UM2: f64 = 300.0;
/// Fixed control block (timing generation, address latches), µm².
const CTRL_FIXED_UM2: f64 = 1200.0;
/// Extra per bank/subarray instance (local decoders, edge cells), µm².
const SUBARRAY_FIXED_UM2: f64 = 350.0;

/// Area model.
pub fn area(spec: &SramSpec) -> SramArea {
    let cells = spec.total_cells() as f64;
    let subarrays = (spec.banks * spec.subarrays) as f64;
    let cell_array = cells * CELL_EFF_UM2;
    let periphery = spec.rows as f64 * ROW_PERIPH_UM2
        + spec.phys_cols() as f64 * COL_PERIPH_UM2
        + CTRL_FIXED_UM2
        + (subarrays - 1.0) * SUBARRAY_FIXED_UM2;
    SramArea {
        cell_array_um2: cell_array,
        periphery_um2: periphery,
        total_um2: cell_array + periphery,
    }
}

// --- timing -------------------------------------------------------------

/// Fixed decoder + timing-control overhead (ns); dominated by the
/// self-timed control chain in small macros — the reason Table II's delay
/// barely moves from 16×8 to 64×32.
const T_CTRL_FIXED_NS: f64 = 4.30;
/// Per-decoder-stage delay (ns).
const T_DEC_STAGE_NS: f64 = 0.055;
/// Sense-amp resolve + output-driver delay (ns).
const T_SA_NS: f64 = 0.35;
/// Bit-line swing required by the SA, V.
const BL_SWING_V: f64 = 0.10;

/// Timing model. `read_current_a` lets the yield engine inject a sampled
/// (mismatch-affected) cell current; pass `None` for the nominal cell.
pub fn timing(spec: &SramSpec, read_current_a: Option<f64>) -> SramTiming {
    let rows_per_sub = spec.rows_per_subarray() as f64;
    let phys_cols = spec.phys_cols() as f64;
    // Decoder: one stage per address bit.
    let stages = (usize::BITS - (spec.rows - 1).leading_zeros()) as f64;
    let decoder_ns = T_CTRL_FIXED_NS + stages * T_DEC_STAGE_NS;
    // Word line: distributed RC across the physical columns (Elmore, 0.38
    // factor), driven once per subarray row.
    let r_wl = process::RWL_PER_CELL_OHM * phys_cols;
    let c_wl = process::CWL_PER_CELL_FF * phys_cols * 1e-15;
    let wordline_ns = 0.38 * r_wl * c_wl * 1e9 + spec.timing.wl_pulse_ps * 1e-3 * 0.0; // pulse width is a constraint, not a delay
    // Bit line: C_bl × ΔV / I_read.
    let c_bl = process::CBL_PER_CELL_FF * rows_per_sub * 1e-15;
    let i_read = read_current_a.unwrap_or(35e-6);
    let bitline_ns = c_bl * BL_SWING_V / i_read * 1e9;
    let sense_ns = T_SA_NS + spec.timing.sae_delay_ps * 1e-3;
    SramTiming {
        decoder_ns,
        wordline_ns,
        bitline_ns,
        sense_ns,
        access_ns: decoder_ns + wordline_ns + bitline_ns + sense_ns,
    }
}

// --- power --------------------------------------------------------------

/// Precharge + BL swing + WL + decoder energy per read access, calibrated
/// to land SRAM read power near 1–2 ×10⁻⁴ W at 100 MHz for the 16×8 macro
/// (Table II's totals are 2–3 ×10⁻⁴ W including logic).
const E_CTRL_PER_ACCESS_PJ: f64 = 0.9;
/// Leakage per cell, nW (45 nm 6T-class, with periphery share folded in).
const LEAK_PER_CELL_NW: f64 = 45.0;

/// Power model at a given access rate (reads/s).
pub fn power(spec: &SramSpec, access_hz: f64) -> SramPower {
    let rows_per_sub = spec.rows_per_subarray() as f64;
    let phys_cols = spec.phys_cols() as f64;
    let vdd = process::VDD;
    // Per access: precharge+swing on every physical column of the active
    // subarray, full-swing WL, decoder/control.
    let c_bl = process::CBL_PER_CELL_FF * rows_per_sub; // fF
    let e_bl_pj = phys_cols * c_bl * vdd * BL_SWING_V * 1e-3; // fF·V² → pJ·1e-3
    let c_wl = process::CWL_PER_CELL_FF * phys_cols; // fF
    let e_wl_pj = c_wl * vdd * vdd * 1e-3;
    let e_access_pj = e_bl_pj + e_wl_pj + E_CTRL_PER_ACCESS_PJ;
    SramPower {
        read_dynamic_w: e_access_pj * 1e-12 * access_hz,
        leakage_w: spec.total_cells() as f64 * LEAK_PER_CELL_NW * 1e-9,
    }
}

/// Convenience: full PPA snapshot for a generated macro at an access rate.
pub fn characterize(m: &SramMacro, access_hz: f64) -> (SramArea, SramTiming, SramPower) {
    (
        area(&m.spec),
        timing(&m.spec, None),
        power(&m.spec, access_hz),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SramSpec;

    #[test]
    fn area_lands_in_paper_envelope() {
        // Paper Table II: ~7052 / 16910 / 48042 µm² — accept ±30%.
        let cases = [(16usize, 8usize, 7052.0), (32, 16, 16910.0), (64, 32, 48042.0)];
        for (rows, bits, target) in cases {
            let a = area(&SramSpec::new(rows, bits)).total_um2;
            let ratio = a / target;
            assert!(
                (0.7..1.3).contains(&ratio),
                "{rows}x{bits}: {a:.0} vs paper {target} (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn access_time_is_five_ns_class_and_nearly_flat() {
        let t8 = timing(&SramSpec::new(16, 8), None).access_ns;
        let t16 = timing(&SramSpec::new(32, 16), None).access_ns;
        let t32 = timing(&SramSpec::new(64, 32), None).access_ns;
        for (t, name) in [(t8, "16x8"), (t16, "32x16"), (t32, "64x32")] {
            assert!((4.8..5.8).contains(&t), "{name} access {t:.2} ns");
        }
        assert!(t32 > t8, "bigger macro must be (slightly) slower");
        assert!(t32 - t8 < 0.6, "delay should be nearly flat like Table II");
    }

    #[test]
    fn weak_cell_slows_access() {
        let spec = SramSpec::new(64, 32);
        let nominal = timing(&spec, Some(35e-6)).access_ns;
        let weak = timing(&spec, Some(5e-6)).access_ns;
        assert!(weak > nominal + 0.1);
    }

    #[test]
    fn power_scales_with_size_and_rate() {
        let p_small = power(&SramSpec::new(16, 8), 100e6);
        let p_big = power(&SramSpec::new(64, 32), 100e6);
        assert!(p_big.total_w() > p_small.total_w());
        let p_half_rate = power(&SramSpec::new(16, 8), 50e6);
        assert!(
            (p_half_rate.read_dynamic_w - p_small.read_dynamic_w / 2.0).abs()
                < 1e-12
        );
        // 16×8 at 100 MHz ~1e-4 W class.
        let w = p_small.total_w();
        assert!((1e-5..1e-3).contains(&w), "sram power {w}");
    }

    #[test]
    fn banking_shortens_bitlines() {
        let flat = SramSpec::new(64, 8);
        let mut banked = SramSpec::new(64, 8);
        banked.subarrays = 4;
        let t_flat = timing(&flat, None).bitline_ns;
        let t_banked = timing(&banked, None).bitline_ns;
        assert!(t_banked < t_flat);
    }
}
