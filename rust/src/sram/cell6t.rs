//! 6T bit-cell DC analysis: operating points, butterfly curves, static
//! noise margins (read / write / hold) and read current — all as functions
//! of the six per-transistor threshold voltages, which carry the sampled
//! local mismatch for Monte-Carlo / importance-sampling yield analysis.
//!
//! Topology (paper Fig 4 cell):
//!
//! ```text
//!          VDD            VDD
//!           |              |
//!         [PU1]          [PU2]
//!  BL --[PG1]-- Q ---x--- QB --[PG2]-- BLB
//!         [PD1]          [PD2]
//!           |              |
//!          GND            GND
//! ```
//!
//! PU/PD gates cross-coupled (gate of left pair = QB, right pair = Q);
//! PG gates on the word line.

use super::device::{process, Mosfet};

/// Per-transistor ΔVth sample (local mismatch), in the order
/// [PD1, PU1, PG1, PD2, PU2, PG2].
pub type VthDeltas = [f64; 6];

/// 6T cell with explicit sizing (W in multiples of minimum width).
/// Default sizing is the classic read-stable ratioing: PD = 2.0,
/// PG = 1.2, PU = 1.0.
#[derive(Clone, Copy, Debug)]
pub struct Cell6T {
    pub wpd: f64,
    pub wpu: f64,
    pub wpg: f64,
    /// ΔVth per device.
    pub dvth: VthDeltas,
}

impl Default for Cell6T {
    fn default() -> Self {
        Self {
            wpd: 2.0,
            wpu: 1.0,
            wpg: 1.2,
            dvth: [0.0; 6],
        }
    }
}

/// σ(Vth) per device for this sizing, Pelgrom law (used by the samplers).
pub fn sigma_vth(cell: &Cell6T) -> [f64; 6] {
    let s = |w: f64| process::AVT / w.sqrt();
    [
        s(cell.wpd),
        s(cell.wpu),
        s(cell.wpg),
        s(cell.wpd),
        s(cell.wpu),
        s(cell.wpg),
    ]
}

/// SNM results, V.
#[derive(Clone, Copy, Debug, Default)]
pub struct SnmReport {
    pub read_snm: f64,
    pub hold_snm: f64,
    /// Write margin: how far below VDD the non-driven internal node is
    /// pulled during a write (larger = easier write). Negative = write fail.
    pub write_margin: f64,
    /// Read current of the accessed cell, A (drives BL discharge).
    pub read_current: f64,
}

#[derive(Clone, Copy)]
struct HalfCell {
    pd: Mosfet,
    pu: Mosfet,
    pg: Mosfet,
}

impl Cell6T {
    fn half(&self, left: bool) -> HalfCell {
        let o = if left { 0 } else { 3 };
        HalfCell {
            pd: Mosfet::nmos(self.wpd, process::VTHN0 + self.dvth[o]),
            pu: Mosfet::pmos(self.wpu, process::VTHP0 + self.dvth[o + 1]),
            pg: Mosfet::nmos(self.wpg, process::VTHN0 + self.dvth[o + 2]),
        }
    }

    /// Solve the internal-node voltage of one half-cell given the opposite
    /// node voltage `vin`, under a given access condition.
    ///
    /// * `wl` — word-line voltage (0 = hold);
    /// * `bl` — bit-line voltage at the access transistor.
    ///
    /// Node equation at V: I_pd(V) + I_pg_out(V) = I_pu(V) + I_pg_in(V)
    /// solved by bisection (the net pull-down current is monotone in V).
    fn solve_node(&self, half: &HalfCell, vin: f64, wl: f64, bl: f64) -> f64 {
        let vdd = process::VDD;
        // Net current *into* the node as a function of node voltage v:
        // pull-up from VDD (PU, gate = vin), pull-in/out through PG
        // (gate = wl, source/drain = bl), pull-down via PD (gate = vin).
        let f = |v: f64| -> f64 {
            let i_pu = half.pu.id(vdd - vin, vdd - v); // |Vgs|, |Vds| of PMOS
            let i_pd = half.pd.id(vin, v);
            // Access transistor: conducts from BL to node when BL > V
            // (source at node), from node to BL otherwise (source at BL).
            let i_pg = if bl >= v {
                half.pg.id(wl - v, bl - v) // charging the node
            } else {
                -half.pg.id(wl - bl, v - bl) // discharging the node
            };
            i_pu + i_pg - i_pd
        };
        // Bisection: f is decreasing in v.
        let (mut lo, mut hi) = (0.0f64, vdd);
        let (flo, fhi) = (f(lo), f(hi));
        if flo <= 0.0 {
            return 0.0;
        }
        if fhi >= 0.0 {
            return vdd;
        }
        // 42 bisection iterations resolve ~2.5e-13 V — far below any
        // criterion; 60 was measured 30% slower for no accuracy gain (§Perf).
        for _ in 0..42 {
            let mid = 0.5 * (lo + hi);
            if f(mid) > 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    /// Voltage-transfer curve of one half-cell: sweep the opposite node and
    /// record this node's voltage. `read` selects read condition (WL = VDD,
    /// BL precharged to VDD) vs hold (WL = 0).
    fn vtc(&self, left: bool, read: bool, points: usize) -> Vec<(f64, f64)> {
        let vdd = process::VDD;
        let half = self.half(left);
        let (wl, bl) = if read { (vdd, vdd) } else { (0.0, vdd) };
        (0..points)
            .map(|i| {
                let vin = vdd * i as f64 / (points - 1) as f64;
                (vin, self.solve_node(&half, vin, wl, bl))
            })
            .collect()
    }

    /// Static noise margin from the two butterfly lobes: the side of the
    /// largest square nested between VTC₁(x) and VTC₂⁻¹(x), computed with
    /// the classic 45°-rotation method.
    fn snm_from_vtcs(c1: &[(f64, f64)], c2: &[(f64, f64)]) -> f64 {
        // Curve A: (x, y) from c1. Curve B: mirrored c2 → (y, x).
        // A 45° line y = x + c has constant u = (x − y)/√2 = −c/√2; the
        // largest square nested in a lobe has both diagonal corners on one
        // such line, so its side = (eye opening along v at that u) / √2.
        // In the upper-left lobe (u < 0) curve A bounds the eye from above
        // and curve B from below; in the lower-right lobe it is reversed.
        // Eye opening = upper curve's highest branch − lower curve's
        // lowest branch at that u.
        let rot = |pts: &[(f64, f64)], mirror: bool| -> Vec<(f64, f64)> {
            pts.iter()
                .map(|&(x, y)| {
                    let (x, y) = if mirror { (y, x) } else { (x, y) };
                    let u = (x - y) / std::f64::consts::SQRT_2;
                    let v = (x + y) / std::f64::consts::SQRT_2;
                    (u, v)
                })
                .collect()
        };
        let a = rot(c1, false);
        let b = rot(c2, true);
        // All branch crossings of a rotated polyline at a given u.
        let branches = |pts: &[(f64, f64)], u: f64| -> Option<(f64, f64)> {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for w in pts.windows(2) {
                let (u0, v0) = w[0];
                let (u1, v1) = w[1];
                let (ulo, uhi) = if u0 <= u1 { (u0, u1) } else { (u1, u0) };
                if u >= ulo && u <= uhi && (u1 - u0).abs() > 1e-12 {
                    let t = (u - u0) / (u1 - u0);
                    let v = v0 + t * (v1 - v0);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            if lo.is_finite() {
                Some((lo, hi))
            } else {
                None
            }
        };
        let mut lobe_neg = 0f64;
        let mut lobe_pos = 0f64;
        let umax = process::VDD / std::f64::consts::SQRT_2;
        let n = 200;
        for i in 0..=n {
            let u = -umax + 2.0 * umax * i as f64 / n as f64;
            if let (Some((a_lo, a_hi)), Some((b_lo, b_hi))) =
                (branches(&a, u), branches(&b, u))
            {
                if u < 0.0 {
                    // A above, B below.
                    let side = (a_hi - b_lo) / std::f64::consts::SQRT_2;
                    lobe_neg = lobe_neg.max(side);
                } else {
                    let side = (b_hi - a_lo) / std::f64::consts::SQRT_2;
                    lobe_pos = lobe_pos.max(side);
                }
            }
        }
        lobe_neg.min(lobe_pos)
    }

    /// Debug helper: expose the butterfly VTCs (used by tooling/tests).
    #[doc(hidden)]
    pub fn debug_vtc(&self, left: bool, read: bool, points: usize) -> Vec<(f64, f64)> {
        self.vtc(left, read, points)
    }

    /// Fast path for the yield engine: read SNM + write margin + read
    /// current only (skips the hold butterfly), with a coarser VTC grid.
    /// ~4× cheaper than [`Cell6T::characterize`]; the Monte-Carlo loop is
    /// the hottest path in the whole compiler (see EXPERIMENTS.md §Perf).
    pub fn characterize_read(&self) -> SnmReport {
        let vdd = process::VDD;
        let pts = 49;
        let r1 = self.vtc(true, true, pts);
        let r2 = self.vtc(false, true, pts);
        let read_snm = Self::snm_from_vtcs(&r1, &r2);
        let half_l = self.half(true);
        let v_q = self.solve_node(&half_l, vdd, vdd, 0.0);
        let h2c = self.vtc(false, false, 31);
        let mut v_trip = vdd / 2.0;
        for w in h2c.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if (y0 - x0) * (y1 - x1) <= 0.0 {
                v_trip = 0.5 * (x0 + x1);
                break;
            }
        }
        let read_current = {
            let half = self.half(true);
            let v_read = self.solve_node(&half, vdd, vdd, vdd);
            half.pg
                .id(vdd - v_read, vdd - v_read)
                .min(half.pd.id(vdd, v_read.max(0.02)))
        };
        SnmReport {
            read_snm,
            hold_snm: f64::NAN,
            write_margin: v_trip - v_q,
            read_current,
        }
    }

    /// Full characterization of one sample.
    pub fn characterize(&self) -> SnmReport {
        let vdd = process::VDD;
        let pts = 81;
        // Read SNM: both halves under read stress.
        let r1 = self.vtc(true, true, pts);
        let r2 = self.vtc(false, true, pts);
        let read_snm = Self::snm_from_vtcs(&r1, &r2);
        // Hold SNM.
        let h1 = self.vtc(true, false, pts);
        let h2 = self.vtc(false, false, pts);
        let hold_snm = Self::snm_from_vtcs(&h1, &h2);
        // Write margin: drive BL=0 on the Q side (storing 1), WL on; the
        // write succeeds if Q is pulled below the switching threshold of
        // the opposite inverter. Margin = V_trip − V_q_driven.
        let half_l = self.half(true);
        let v_q = self.solve_node(&half_l, vdd, vdd, 0.0); // QB=1 assumed, BL=0
        // Opposite inverter trip point ≈ voltage where VTC crosses y = x.
        let h2c = self.vtc(false, false, pts);
        let mut v_trip = vdd / 2.0;
        for w in h2c.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if (y0 - x0) * (y1 - x1) <= 0.0 {
                v_trip = 0.5 * (x0 + x1);
                break;
            }
        }
        let write_margin = v_trip - v_q;
        // Read current: PG in series with PD discharging the precharged BL
        // through the "0" node. Worst-case series current at V_node solved.
        let read_current = {
            let half = self.half(true);
            // Node rises to v_read during read; current into BL limited by
            // the smaller of PG (sat) and PD (triode) — take the solved
            // operating point.
            let v_read = self.solve_node(&half, vdd, vdd, vdd);
            half.pg.id(vdd - v_read, vdd - v_read).min(half.pd.id(vdd, v_read.max(0.02)))
        };
        SnmReport {
            read_snm,
            hold_snm,
            write_margin,
            read_current,
        }
    }
}

/// Corner samples for quick checks.
pub struct CellCorners;

impl CellCorners {
    /// Nominal cell, no mismatch.
    pub fn nominal() -> Cell6T {
        Cell6T::default()
    }

    /// A heavily skewed cell (weak PD1 / strong PG1) that degrades read SNM.
    pub fn read_weak(skew: f64) -> Cell6T {
        let mut c = Cell6T::default();
        c.dvth[0] = skew; // PD1 slower
        c.dvth[2] = -skew; // PG1 stronger
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_cell_is_stable() {
        let r = CellCorners::nominal().characterize();
        // 45 nm-class 6T: hold SNM a few hundred mV, read SNM ~100-250 mV.
        assert!(
            r.hold_snm > 0.25 && r.hold_snm < 0.6,
            "hold snm {}",
            r.hold_snm
        );
        assert!(
            r.read_snm > 0.05 && r.read_snm < r.hold_snm,
            "read snm {}",
            r.read_snm
        );
        assert!(r.write_margin > 0.0, "write margin {}", r.write_margin);
        assert!(
            r.read_current > 1e-6 && r.read_current < 1e-3,
            "iread {}",
            r.read_current
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn read_snm_degrades_monotonically_with_skew() {
        let mut prev = f64::INFINITY;
        for i in 0..5 {
            let skew = 0.03 * i as f64;
            let r = CellCorners::read_weak(skew).characterize();
            assert!(
                r.read_snm <= prev + 1e-6,
                "snm increased at skew {skew}: {} > {prev}",
                r.read_snm
            );
            prev = r.read_snm;
        }
    }

    #[test]
    fn extreme_mismatch_fails_read_stability() {
        let r = CellCorners::read_weak(0.25).characterize();
        assert!(
            r.read_snm < 0.06,
            "extreme skew should crush read SNM, got {}",
            r.read_snm
        );
    }

    #[test]
    fn stronger_pd_improves_read_snm() {
        let mut big_pd = Cell6T::default();
        big_pd.wpd = 3.0;
        let base = Cell6T::default().characterize().read_snm;
        let improved = big_pd.characterize().read_snm;
        assert!(
            improved > base,
            "wpd 3.0 read snm {improved} <= base {base}"
        );
    }

    #[test]
    fn weaker_pg_improves_read_but_hurts_write() {
        let mut weak_pg = Cell6T::default();
        weak_pg.wpg = 0.7;
        let base = Cell6T::default().characterize();
        let w = weak_pg.characterize();
        assert!(w.read_snm > base.read_snm);
        assert!(w.write_margin < base.write_margin);
    }

    #[test]
    fn vth_shift_reduces_read_current() {
        let mut slow = Cell6T::default();
        slow.dvth[2] = 0.15; // slow PG1
        let base = Cell6T::default().characterize().read_current;
        let s = slow.characterize().read_current;
        assert!(s < base);
    }

    #[test]
    fn sigma_follows_sizing() {
        let c = Cell6T::default();
        let s = sigma_vth(&c);
        assert!(s[0] < s[1], "wider PD has smaller sigma than PU");
        assert_eq!(s[0], s[3]);
        assert_eq!(s[2], s[5]);
    }
}
