//! Automated transistor sizing optimization (paper §III-D item 1:
//! "together with automated transistor sizing optimizations", from the
//! OpenYield integration).
//!
//! The optimizer searches the (W_PD, W_PU, W_PG) space for the smallest
//! cell that meets read-stability, writeability and read-current targets
//! at the nominal corner plus a k·σ mismatch guard-band — the standard
//! 6T sizing trade-off: stronger PD helps read SNM but costs area;
//! stronger PG helps write margin and read current but hurts read SNM.
//!
//! Strategy: coarse grid over the legal ratio space, feasibility check at
//! the guard-band corners, then pick the feasible point with the smallest
//! total width (area proxy) and locally refine with pattern search.

use anyhow::{bail, Result};

use super::cell6t::{sigma_vth, Cell6T};

/// Sizing requirements.
#[derive(Clone, Copy, Debug)]
pub struct SizingTargets {
    /// Minimum read SNM at the guard-band corner, V.
    pub min_read_snm: f64,
    /// Minimum write margin at the guard-band corner, V.
    pub min_write_margin: f64,
    /// Minimum read current (nominal), A.
    pub min_read_current: f64,
    /// Mismatch guard band in σ (applied in the worst direction).
    pub k_sigma: f64,
}

impl Default for SizingTargets {
    fn default() -> Self {
        Self {
            min_read_snm: 0.12,
            min_write_margin: 0.05,
            min_read_current: 15e-6,
            k_sigma: 3.0,
        }
    }
}

/// Optimization result.
#[derive(Clone, Copy, Debug)]
pub struct SizingResult {
    pub wpd: f64,
    pub wpu: f64,
    pub wpg: f64,
    /// Total width (area proxy, in min-width units, ×2 for both halves).
    pub total_width: f64,
    /// Guard-banded metrics at the chosen sizing.
    pub read_snm: f64,
    pub write_margin: f64,
    pub read_current: f64,
    /// Grid + refinement evaluations spent.
    pub evals: u64,
}

fn cell(wpd: f64, wpu: f64, wpg: f64) -> Cell6T {
    Cell6T {
        wpd,
        wpu,
        wpg,
        dvth: [0.0; 6],
    }
}

/// Evaluate the guard-banded metrics for a sizing: read SNM with the
/// read-hostile mismatch corner (slow PD1, fast PG1), write margin with
/// the write-hostile corner (fast PD/PU fighting the write, slow PG),
/// read current with a slow PG.
fn guard_banded(wpd: f64, wpu: f64, wpg: f64, k: f64) -> (f64, f64, f64, u64) {
    let base = cell(wpd, wpu, wpg);
    let sig = sigma_vth(&base);
    let mut evals = 0u64;

    // Read-hostile: PD1 slow (+kσ), PG1 fast (−kσ).
    let mut read_cell = base;
    read_cell.dvth[0] = k * sig[0];
    read_cell.dvth[2] = -k * sig[2];
    let r_read = read_cell.characterize_read();
    evals += 1;

    // Write-hostile: PG1 slow (+kσ), PU2 fast (−kσ) holding the opposite
    // node up (write fights the cross-coupled pull-up).
    let mut write_cell = base;
    write_cell.dvth[2] = k * sig[2];
    write_cell.dvth[4] = -k * sig[4];
    let r_write = write_cell.characterize_read();
    evals += 1;

    // Current-hostile: PG1 and PD1 slow.
    let mut cur_cell = base;
    cur_cell.dvth[0] = k * sig[0];
    cur_cell.dvth[2] = k * sig[2];
    let r_cur = cur_cell.characterize_read();
    evals += 1;

    (
        r_read.read_snm,
        r_write.write_margin,
        r_cur.read_current,
        evals,
    )
}

fn feasible(m: (f64, f64, f64, u64), t: &SizingTargets) -> bool {
    m.0 >= t.min_read_snm && m.1 >= t.min_write_margin && m.2 >= t.min_read_current
}

/// Run the sizing optimization. Widths are bounded to [1, 4] minimum
/// widths (the practical 6T envelope).
pub fn optimize(targets: &SizingTargets) -> Result<SizingResult> {
    let grid = [1.0, 1.25, 1.5, 2.0, 2.5, 3.0, 4.0];
    let mut best: Option<SizingResult> = None;
    let mut total_evals = 0u64;
    for &wpd in &grid {
        for &wpu in &[1.0, 1.25, 1.5] {
            for &wpg in &grid {
                // Classic legality pre-filter: beta ratio (PD/PG) >= 1 for
                // read stability, gamma (PG/PU) >= 1 for writeability.
                if wpd / wpg < 1.0 || wpg / wpu < 0.8 {
                    continue;
                }
                let m = guard_banded(wpd, wpu, wpg, targets.k_sigma);
                total_evals += m.3;
                if !feasible(m, targets) {
                    continue;
                }
                let width = 2.0 * (wpd + wpu + wpg);
                if best
                    .as_ref()
                    .map(|b| width < b.total_width)
                    .unwrap_or(true)
                {
                    best = Some(SizingResult {
                        wpd,
                        wpu,
                        wpg,
                        total_width: width,
                        read_snm: m.0,
                        write_margin: m.1,
                        read_current: m.2,
                        evals: total_evals,
                    });
                }
            }
        }
    }
    let Some(mut incumbent) = best else {
        bail!("no feasible sizing in the search envelope for {targets:?}");
    };
    // Pattern-search refinement (shrink widths while staying feasible).
    let mut step = 0.25;
    while step >= 0.05 {
        let mut improved = false;
        for dim in 0..3 {
            let mut cand = incumbent;
            match dim {
                0 => cand.wpd = (cand.wpd - step).max(1.0),
                1 => cand.wpu = (cand.wpu - step).max(1.0),
                _ => cand.wpg = (cand.wpg - step).max(1.0),
            }
            if cand.wpd / cand.wpg < 1.0 || cand.wpg / cand.wpu < 0.8 {
                continue;
            }
            let m = guard_banded(cand.wpd, cand.wpu, cand.wpg, targets.k_sigma);
            total_evals += m.3;
            if feasible(m, targets) {
                let width = 2.0 * (cand.wpd + cand.wpu + cand.wpg);
                if width < incumbent.total_width {
                    incumbent = SizingResult {
                        total_width: width,
                        read_snm: m.0,
                        write_margin: m.1,
                        read_current: m.2,
                        evals: total_evals,
                        ..cand
                    };
                    improved = true;
                }
            }
        }
        if !improved {
            step *= 0.5;
        }
    }
    incumbent.evals = total_evals;
    Ok(incumbent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn default_targets_have_a_feasible_sizing() {
        let r = optimize(&SizingTargets::default()).unwrap();
        assert!(r.wpd >= r.wpg, "beta ratio respected: {r:?}");
        assert!(r.read_snm >= 0.12);
        assert!(r.write_margin >= 0.05);
        assert!(r.read_current >= 15e-6);
        assert!(r.total_width <= 2.0 * (4.0 + 1.5 + 4.0));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn tighter_snm_target_costs_area() {
        let loose = optimize(&SizingTargets {
            min_read_snm: 0.10,
            ..Default::default()
        })
        .unwrap();
        let tight = optimize(&SizingTargets {
            min_read_snm: 0.17,
            ..Default::default()
        })
        .unwrap();
        assert!(
            tight.total_width >= loose.total_width,
            "tight {tight:?} vs loose {loose:?}"
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn impossible_targets_error_cleanly() {
        let e = optimize(&SizingTargets {
            min_read_snm: 0.5, // above the hold SNM — unreachable
            ..Default::default()
        });
        assert!(e.is_err());
    }
}
