//! MOSFET device model for transistor-level SRAM characterization.
//!
//! A square-law long-channel model with a first-order velocity-saturation
//! correction — the classic hand-analysis model, adequate for the
//! *statistical geometry* of SRAM failure analysis (what Table V needs):
//! failure boundaries move monotonically and smoothly with per-device Vth,
//! which is the property importance sampling exploits. Parameters are
//! FreePDK45-class (45 nm, VDD = 1.1 V).

/// Device polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MosType {
    Nmos,
    Pmos,
}

/// One transistor instance: geometry + threshold (the threshold carries the
/// sampled process variation).
#[derive(Clone, Copy, Debug)]
pub struct Mosfet {
    pub mos_type: MosType,
    /// Width in multiples of minimum width (W/Wmin).
    pub w: f64,
    /// Length in multiples of minimum length (L/Lmin).
    pub l: f64,
    /// Threshold voltage, V (positive magnitude for both types).
    pub vth: f64,
}

/// FreePDK45-class process constants.
pub mod process {
    /// Supply voltage, V.
    pub const VDD: f64 = 1.1;
    /// NMOS transconductance at minimum W/L, A/V².
    pub const KN: f64 = 250e-6;
    /// PMOS transconductance at minimum W/L, A/V².
    pub const KP: f64 = 110e-6;
    /// Nominal NMOS threshold, V.
    pub const VTHN0: f64 = 0.40;
    /// Nominal PMOS threshold magnitude, V.
    pub const VTHP0: f64 = 0.38;
    /// Channel-length modulation, 1/V.
    pub const LAMBDA: f64 = 0.08;
    /// Velocity-saturation critical voltage, V (lower → stronger v-sat).
    pub const VSAT_V: f64 = 1.0;
    /// Pelgrom coefficient A_Vt, V·(unit area)^0.5 — σ(Vth) = AVT/sqrt(W·L).
    /// Calibrated so a minimum device has σ ≈ 35 mV (45 nm class).
    pub const AVT: f64 = 0.035;
    /// Minimum-width device gate capacitance, fF.
    pub const CGATE_MIN_FF: f64 = 0.08;
    /// Bit-line junction capacitance per cell, fF.
    pub const CBL_PER_CELL_FF: f64 = 0.18;
    /// Word-line capacitance per cell (gate of two access devices), fF.
    pub const CWL_PER_CELL_FF: f64 = 0.20;
    /// Word-line wire resistance per cell pitch, Ω.
    pub const RWL_PER_CELL_OHM: f64 = 12.0;
}

impl Mosfet {
    pub fn nmos(w: f64, vth: f64) -> Self {
        Self {
            mos_type: MosType::Nmos,
            w,
            l: 1.0,
            vth,
        }
    }

    pub fn pmos(w: f64, vth: f64) -> Self {
        Self {
            mos_type: MosType::Pmos,
            w,
            l: 1.0,
            vth,
        }
    }

    /// σ(Vth) from the Pelgrom law for this geometry.
    pub fn sigma_vth(&self) -> f64 {
        process::AVT / (self.w * self.l).sqrt()
    }

    /// Drain current magnitude, A.
    ///
    /// For NMOS: `vgs`, `vds` are gate-source / drain-source voltages
    /// (source at the lower-potential terminal). For PMOS pass the
    /// *magnitudes* |Vgs|, |Vds| — the model is symmetric.
    pub fn id(&self, vgs: f64, vds: f64) -> f64 {
        if vds <= 0.0 {
            return 0.0;
        }
        let vov = vgs - self.vth;
        if vov <= 0.0 {
            // Sub-threshold: exponential, small but non-zero so solvers see
            // a smooth function. n·VT ≈ 36 mV.
            let k = self.k();
            let i0 = 0.1 * k * 0.036 * 0.036;
            return self.w / self.l * i0 * ((vov / 0.036).exp()).min(1.0)
                * (1.0 - (-vds / 0.026).exp());
        }
        // Velocity-saturation-corrected overdrive.
        let vov_eff = vov / (1.0 + vov / process::VSAT_V);
        let k = self.k() * self.w / self.l;
        if vds >= vov_eff {
            // Saturation.
            0.5 * k * vov_eff * vov_eff * (1.0 + process::LAMBDA * vds)
        } else {
            // Triode.
            k * (vov_eff * vds - 0.5 * vds * vds)
        }
    }

    fn k(&self) -> f64 {
        match self.mos_type {
            MosType::Nmos => process::KN,
            MosType::Pmos => process::KP,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cutoff_and_saturation_regions() {
        let m = Mosfet::nmos(1.0, process::VTHN0);
        // Deep cutoff ≈ 0.
        assert!(m.id(0.0, 1.1) < 1e-9);
        // Saturation current positive and increasing with Vgs.
        let i1 = m.id(0.8, 1.1);
        let i2 = m.id(1.1, 1.1);
        assert!(i1 > 1e-6);
        assert!(i2 > i1);
    }

    #[test]
    fn triode_less_than_saturation() {
        let m = Mosfet::nmos(1.0, process::VTHN0);
        let i_sat = m.id(1.1, 1.1);
        let i_tri = m.id(1.1, 0.05);
        assert!(i_tri < i_sat);
        assert!(i_tri > 0.0);
    }

    #[test]
    fn width_scales_current() {
        let m1 = Mosfet::nmos(1.0, process::VTHN0);
        let m2 = Mosfet::nmos(2.0, process::VTHN0);
        let r = m2.id(1.1, 1.1) / m1.id(1.1, 1.1);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn vth_shift_reduces_current() {
        let nom = Mosfet::nmos(1.0, process::VTHN0);
        let slow = Mosfet::nmos(1.0, process::VTHN0 + 0.1);
        assert!(slow.id(1.1, 1.1) < nom.id(1.1, 1.1));
    }

    #[test]
    fn pelgrom_sigma() {
        let min_dev = Mosfet::nmos(1.0, process::VTHN0);
        let wide = Mosfet::nmos(4.0, process::VTHN0);
        assert!((min_dev.sigma_vth() - 0.035).abs() < 1e-12);
        assert!((wide.sigma_vth() - 0.0175).abs() < 1e-12);
    }

    #[test]
    fn current_is_continuous_at_region_boundaries() {
        let m = Mosfet::nmos(1.5, process::VTHN0);
        // Across the triode/saturation boundary.
        let vov_eff = {
            let vov = 1.1 - m.vth;
            vov / (1.0 + vov / process::VSAT_V)
        };
        let below = m.id(1.1, vov_eff - 1e-6);
        let above = m.id(1.1, vov_eff + 1e-6);
        assert!((below - above).abs() / above < 0.05);
    }
}
