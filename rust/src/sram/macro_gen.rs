//! SRAM macro generator: organization (banks / subarrays / column mux),
//! peripheral enumeration (hierarchical WL decoders and drivers, precharge,
//! write drivers, sense amps) and a cycle-level behavioral model used by
//! the PE simulator and the coordinator's energy accounting.

use anyhow::{bail, Result};

use crate::config::spec::SramSpec;

/// Peripheral inventory of a generated macro (per physical subarray and
/// total) — the input to the area/power models and the LEF/LIB emitters.
#[derive(Clone, Debug)]
pub struct Periphery {
    pub decoder_stages: usize,
    pub wl_drivers: usize,
    pub precharge_units: usize,
    pub write_drivers: usize,
    pub sense_amps: usize,
    pub column_mux_legs: usize,
}

/// A generated SRAM macro: organization + storage behavioral model.
#[derive(Clone, Debug)]
pub struct SramMacro {
    pub spec: SramSpec,
    pub periphery: Periphery,
    /// Word storage (behavioral), rows × word_bits.
    data: Vec<u64>,
    /// Read/write access counters for energy accounting.
    pub reads: u64,
    pub writes: u64,
}

impl SramMacro {
    /// Generate a macro from a validated spec.
    pub fn generate(spec: &SramSpec) -> Result<SramMacro> {
        spec.validate()?;
        let rows_per_sub = spec.rows_per_subarray();
        if rows_per_sub < 2 {
            bail!("subarray would have < 2 rows");
        }
        let phys_cols = spec.phys_cols();
        let subarrays = spec.banks * spec.subarrays;
        let periphery = Periphery {
            // log2(rows) address bits, decoded hierarchically: a bank/
            // subarray predecoder stage plus a final row decoder stage.
            decoder_stages: (usize::BITS - (spec.rows - 1).leading_zeros()) as usize,
            wl_drivers: rows_per_sub * subarrays,
            precharge_units: phys_cols * subarrays,
            write_drivers: spec.word_bits * subarrays,
            sense_amps: spec.word_bits * subarrays,
            column_mux_legs: if spec.mux_ratio > 1 {
                phys_cols * subarrays
            } else {
                0
            },
        };
        Ok(SramMacro {
            spec: spec.clone(),
            periphery,
            data: vec![0; spec.rows],
            reads: 0,
            writes: 0,
        })
    }

    /// Behavioral write of a word.
    pub fn write(&mut self, row: usize, value: u64) -> Result<()> {
        if row >= self.spec.rows {
            bail!("row {row} out of range {}", self.spec.rows);
        }
        let mask = if self.spec.word_bits == 64 {
            u64::MAX
        } else {
            (1u64 << self.spec.word_bits) - 1
        };
        if value & !mask != 0 {
            bail!("value {value:#x} exceeds word width {}", self.spec.word_bits);
        }
        self.data[row] = value;
        self.writes += 1;
        Ok(())
    }

    /// Behavioral read of a word.
    pub fn read(&mut self, row: usize) -> Result<u64> {
        if row >= self.spec.rows {
            bail!("row {row} out of range {}", self.spec.rows);
        }
        self.reads += 1;
        Ok(self.data[row])
    }

    /// Load a slice of words starting at row 0 (weight initialisation).
    pub fn load(&mut self, words: &[u64]) -> Result<()> {
        if words.len() > self.spec.rows {
            bail!("{} words exceed {} rows", words.len(), self.spec.rows);
        }
        for (i, &w) in words.iter().enumerate() {
            self.write(i, w)?;
        }
        Ok(())
    }

    /// Which bank/subarray/local row an address maps to (interleaved:
    /// low bits select the bank for conflict-free sequential streaming).
    pub fn address_map(&self, row: usize) -> (usize, usize, usize) {
        let banks = self.spec.banks;
        let subs = self.spec.subarrays;
        let bank = row % banks;
        let sub = (row / banks) % subs;
        let local = row / (banks * subs);
        (bank, sub, local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SramSpec;

    #[test]
    fn generate_paper_configs() {
        for (rows, bits) in [(16, 8), (32, 16), (64, 32)] {
            let spec = SramSpec::new(rows, bits);
            let m = SramMacro::generate(&spec).unwrap();
            assert_eq!(m.periphery.sense_amps, bits);
            assert_eq!(m.periphery.wl_drivers, rows);
            assert_eq!(
                m.periphery.decoder_stages,
                (usize::BITS - (rows - 1).leading_zeros()) as usize
            );
        }
    }

    #[test]
    fn read_write_roundtrip() {
        let mut m = SramMacro::generate(&SramSpec::new(16, 8)).unwrap();
        for row in 0..16 {
            m.write(row, (row as u64 * 17) & 0xFF).unwrap();
        }
        for row in 0..16 {
            assert_eq!(m.read(row).unwrap(), (row as u64 * 17) & 0xFF);
        }
        assert_eq!(m.writes, 16);
        assert_eq!(m.reads, 16);
    }

    #[test]
    fn bounds_and_width_checks() {
        let mut m = SramMacro::generate(&SramSpec::new(16, 8)).unwrap();
        assert!(m.write(16, 0).is_err());
        assert!(m.write(0, 0x100).is_err());
        assert!(m.read(99).is_err());
    }

    #[test]
    fn banked_address_mapping_covers_all_rows() {
        let mut spec = SramSpec::new(64, 8);
        spec.banks = 2;
        spec.subarrays = 2;
        let m = SramMacro::generate(&spec).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for row in 0..64 {
            let (b, s, l) = m.address_map(row);
            assert!(b < 2 && s < 2 && l < 16);
            seen.insert((b, s, l));
        }
        assert_eq!(seen.len(), 64, "mapping must be injective");
    }

    #[test]
    fn mux_ratio_expands_columns() {
        let mut spec = SramSpec::new(64, 8);
        spec.mux_ratio = 4;
        let m = SramMacro::generate(&spec).unwrap();
        assert_eq!(m.periphery.precharge_units, 32);
        assert_eq!(m.periphery.sense_amps, 8);
        assert!(m.periphery.column_mux_legs > 0);
    }
}
