//! Dynamic batcher: drain-until-full-or-timeout batching policy.
//!
//! Generic over the payload so it is testable without PJRT: the policy
//! invariants (no request lost, none duplicated, batch size bounded,
//! FIFO order preserved within a variant) are property-tested here.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Drain the next batch from a receiver. Blocks until at least one item is
/// available (or the channel closes — returns None). After the first item,
/// keeps collecting until `max_batch` or `max_wait` since the first item.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::thread;

    #[test]
    fn collects_full_batch_when_queue_is_hot() {
        let (tx, rx) = channel();
        for i in 0..100 {
            tx.send(i).unwrap();
        }
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(50),
        };
        let b1 = next_batch(&rx, &p).unwrap();
        assert_eq!(b1.len(), 32);
        assert_eq!(b1[0], 0);
        let b2 = next_batch(&rx, &p).unwrap();
        assert_eq!(b2[0], 32, "FIFO order across batches");
    }

    #[test]
    fn partial_batch_on_timeout() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        tx.send(2).unwrap();
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
        };
        let b = next_batch(&rx, &p).unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn no_request_lost_or_duplicated_under_concurrency() {
        let (tx, rx) = channel();
        let n = 5000u64;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..n / 4 {
                        tx.send(p * 1_000_000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
        };
        let mut seen = std::collections::BTreeSet::new();
        let mut total = 0u64;
        while let Some(batch) = next_batch(&rx, &policy) {
            assert!(batch.len() <= 64);
            for item in batch {
                assert!(seen.insert(item), "duplicate {item}");
                total += 1;
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(total, n);
    }

    #[test]
    fn fifo_within_single_producer() {
        let (tx, rx) = channel();
        for i in 0..500u32 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 7,
            max_wait: Duration::from_micros(100),
        };
        let mut last = None;
        while let Some(batch) = next_batch(&rx, &policy) {
            for item in batch {
                if let Some(prev) = last {
                    assert!(item > prev, "order violated: {item} after {prev}");
                }
                last = Some(item);
            }
        }
        assert_eq!(last, Some(499));
    }
}
