//! Dynamic batcher: deadline-bucket batching for the sharded pipeline.
//!
//! A batch closes on whichever comes first — it fills (`max_batch`), the
//! plain `max_wait` window since the first item elapses, or the **SLO
//! deadline** of the most urgent queued request comes within
//! `close_margin`. The third rule is what makes batching SLO-aware: a
//! trickle of requests (slow-loris arrival) still ships each request with
//! `close_margin` of headroom before its deadline instead of idling the
//! full `max_wait` every time, while hot queues keep amortizing at full
//! batch width.
//!
//! Generic over the payload so it is testable without a backend: callers
//! supply `deadline_of` to expose each item's deadline. The policy
//! invariants (no request lost, none duplicated, batch size bounded, FIFO
//! order preserved within a queue, never close later than the most urgent
//! deadline minus the margin) are property-tested here.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Plain batching window since the first item of a batch.
    pub max_wait: Duration,
    /// Default end-to-end latency SLO assigned to requests that carry no
    /// explicit deadline.
    pub slo: Duration,
    /// Close the batch when the most urgent queued deadline is within
    /// this margin — the headroom left for execute + respond.
    pub close_margin: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            slo: Duration::from_millis(50),
            close_margin: Duration::from_millis(5),
        }
    }
}

/// Drain the next batch from a receiver. Blocks until at least one item
/// is available (or the channel closes — returns `None`). After the
/// first item, keeps collecting until `max_batch` items, `max_wait`
/// since the first item, or the earliest `deadline_of(item)` minus
/// `close_margin` — whichever is soonest. Deadlines already past close
/// the batch immediately.
pub fn next_batch<T>(
    rx: &Receiver<T>,
    policy: &BatchPolicy,
    deadline_of: impl Fn(&T) -> Instant,
) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut close_at = Instant::now() + policy.max_wait;
    // Pull the close earlier when a deadline (minus margin) precedes it.
    let mut tighten = |close_at: &mut Instant, item: &T| {
        let latest = deadline_of(item)
            .checked_sub(policy.close_margin)
            .unwrap_or_else(Instant::now);
        if latest < *close_at {
            *close_at = latest;
        }
    };
    tighten(&mut close_at, &first);
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= close_at {
            break;
        }
        match rx.recv_timeout(close_at - now) {
            Ok(item) => {
                tighten(&mut close_at, &item);
                batch.push(item);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::thread;

    /// A far-away constant deadline: the SLO rule never fires, so these
    /// exercise the classic size/timeout behavior.
    fn lax<T>(_item: &T) -> Instant {
        Instant::now() + Duration::from_secs(3600)
    }

    #[test]
    fn collects_full_batch_when_queue_is_hot() {
        let (tx, rx) = channel();
        for i in 0..100 {
            tx.send(i).unwrap();
        }
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let b1 = next_batch(&rx, &p, lax).unwrap();
        assert_eq!(b1.len(), 32);
        assert_eq!(b1[0], 0);
        let b2 = next_batch(&rx, &p, lax).unwrap();
        assert_eq!(b2[0], 32, "FIFO order across batches");
    }

    #[test]
    fn partial_batch_on_timeout() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        tx.send(2).unwrap();
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(5),
            ..BatchPolicy::default()
        };
        let b = next_batch(&rx, &p, lax).unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    #[test]
    fn returns_none_when_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default(), lax).is_none());
    }

    #[test]
    fn urgent_deadline_closes_the_batch_early() {
        let (tx, rx) = channel();
        tx.send(0u32).unwrap();
        // Generous max_wait; the item's deadline is nearly due, so the
        // batch must close on deadline proximity instead.
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_secs(5),
            close_margin: Duration::from_millis(1),
            ..BatchPolicy::default()
        };
        let due = Instant::now() + Duration::from_millis(10);
        let t0 = Instant::now();
        let b = next_batch(&rx, &p, |_| due).unwrap();
        let waited = t0.elapsed();
        assert_eq!(b, vec![0]);
        assert!(
            waited < Duration::from_millis(500),
            "batch held {waited:?} past an imminent deadline"
        );
    }

    #[test]
    fn expired_deadline_closes_immediately() {
        let (tx, rx) = channel();
        tx.send(7u32).unwrap();
        let p = BatchPolicy {
            max_wait: Duration::from_secs(5),
            ..BatchPolicy::default()
        };
        // Deadline in the past: checked_sub path + instant close.
        let due = Instant::now();
        let t0 = Instant::now();
        let b = next_batch(&rx, &p, |_| due).unwrap();
        assert_eq!(b, vec![7]);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn later_urgent_arrival_pulls_the_close_earlier() {
        let (tx, rx) = channel();
        tx.send(0u32).unwrap();
        let urgent_due = Instant::now() + Duration::from_millis(15);
        let handle = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
            // Keep tx alive so the batcher can't close via disconnect
            // before the deadline rule fires.
            thread::sleep(Duration::from_millis(300));
            drop(tx);
        });
        let p = BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_secs(5),
            close_margin: Duration::from_millis(1),
            ..BatchPolicy::default()
        };
        // Item 0 is lax, item 1 is urgent: the batch must close around
        // item 1's deadline, not item 0's.
        let t0 = Instant::now();
        let b = next_batch(
            &rx,
            &p,
            |&i| {
                if i == 0 {
                    Instant::now() + Duration::from_secs(3600)
                } else {
                    urgent_due
                }
            },
        )
        .unwrap();
        assert_eq!(b, vec![0, 1]);
        assert!(t0.elapsed() < Duration::from_millis(250));
        handle.join().unwrap();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_concurrency() {
        let (tx, rx) = channel();
        let n = 5000u64;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..n / 4 {
                        tx.send(p * 1_000_000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            ..BatchPolicy::default()
        };
        let mut seen = std::collections::BTreeSet::new();
        let mut total = 0u64;
        while let Some(batch) = next_batch(&rx, &policy, lax) {
            assert!(batch.len() <= 64);
            for item in batch {
                assert!(seen.insert(item), "duplicate {item}");
                total += 1;
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(total, n);
    }

    #[test]
    fn fifo_within_single_producer() {
        let (tx, rx) = channel();
        for i in 0..500u32 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 7,
            max_wait: Duration::from_micros(100),
            ..BatchPolicy::default()
        };
        let mut last = None;
        while let Some(batch) = next_batch(&rx, &policy, lax) {
            for item in batch {
                if let Some(prev) = last {
                    assert!(item > prev, "order violated: {item} after {prev}");
                }
                last = Some(item);
            }
        }
        assert_eq!(last, Some(499));
    }
}
