//! The inference server: a sharded, SLO-aware front door over the
//! per-shard stage pipelines in [`super::pipeline`].
//!
//! ## Request wire format
//!
//! A [`Request`] carries the image payload, a [`Route`] — either an
//! explicit serving variant (the historical form) or an
//! [`AccuracyClass`], which the [`RoutingTable`] resolves to the cheapest
//! variant whose store-measured calibration accuracy satisfies it (see
//! [`super::router`]) — an optional per-request SLO overriding the
//! server-wide [`BatchPolicy::slo`], and the delivery channel. Every
//! *admitted* request receives exactly one [`Delivery`]: `Ok(Response)`
//! with the logits and the variant that actually served it, or
//! `Failed(FailReason)` when its deadline expired in queue, the backend
//! errored, or a worker panicked. Rejected submissions return a typed
//! [`SubmitError`] instead (malformed / unroutable / shed / shutting
//! down), which is what makes the accounting identity
//! `submitted == delivered + shed + failed` checkable from the outside —
//! the soak and property suites in `rust/tests/serving_shard.rs` assert
//! it across shard counts and adversarial arrival patterns.
//!
//! Requests spread across shards by consistent hashing of the image
//! payload ([`HashRing`]); each shard runs the bounded-channel admission →
//! batch → execute → respond pipeline.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmissionController};
use super::batcher::BatchPolicy;
use super::metrics::ServerMetrics;
use super::pipeline::{
    spawn_shard, FailDisposition, Health, QueuedRequest, ResponseSlot, ShardCtx, ShardPipeline,
};
use super::resilience::{ResilienceConfig, ResilienceRuntime, NO_BREAKER_EPOCH};
use super::router::{AccuracyClass, HashRing, RoutingTable};
use super::warmstart::{profile_for_variant, VariantProfile};
use crate::runtime::backend::IMAGE_BYTES;
use crate::runtime::{ArtifactStore, BackendFactory, PjrtFactory};

/// Where a request wants to execute.
#[derive(Clone, Debug, PartialEq)]
pub enum Route {
    /// A serving variant by name (exact / appro42 / logour / lm / plan).
    Variant(String),
    /// An accuracy constraint; the server picks the cheapest satisfying
    /// variant ([`RoutingTable::select`]).
    Class(AccuracyClass),
}

/// A classification request: one 16×16 grayscale image + routing +
/// optional per-request latency SLO.
pub struct Request {
    pub image: Vec<u8>,
    pub route: Route,
    /// End-to-end deadline budget; `None` uses the server's
    /// [`BatchPolicy::slo`].
    pub slo: Option<Duration>,
    pub respond: Sender<Delivery>,
}

impl Request {
    /// The historical wire format: route by explicit variant, server SLO.
    pub fn to_variant(
        image: Vec<u8>,
        variant: impl Into<String>,
        respond: Sender<Delivery>,
    ) -> Request {
        Request {
            image,
            route: Route::Variant(variant.into()),
            slo: None,
            respond,
        }
    }

    /// Route by accuracy class, server SLO.
    pub fn to_class(image: Vec<u8>, class: AccuracyClass, respond: Sender<Delivery>) -> Request {
        Request {
            image,
            route: Route::Class(class),
            slo: None,
            respond,
        }
    }

    /// Override the per-request latency SLO.
    pub fn with_slo(mut self, slo: Duration) -> Request {
        self.slo = Some(slo);
        self
    }
}

/// The response: 10 logits, the predicted class, and the variant that
/// actually served the request (= the routing decision under class
/// routing; echoes the requested variant otherwise).
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub predicted: usize,
    pub variant: String,
    /// True when the degradation ladder re-routed this class-routed
    /// request off its first-choice variant (breaker open or queue-wait
    /// pressure); the serving variant still satisfies the class unless
    /// it is the flagged exact fallback.
    pub degraded: bool,
}

/// Why an admitted request failed instead of completing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// The SLO deadline passed while the request was still queued.
    DeadlineExpired,
    /// The backend returned an error (or a short batch).
    ExecuteFailed(String),
    /// The executor panicked; the server is unhealthy.
    WorkerPanicked,
}

impl std::fmt::Display for FailReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailReason::DeadlineExpired => write!(f, "deadline expired in queue"),
            FailReason::ExecuteFailed(e) => write!(f, "execute failed: {e}"),
            FailReason::WorkerPanicked => write!(f, "worker panicked"),
        }
    }
}

/// Exactly one of these arrives per admitted request.
#[derive(Clone, Debug)]
pub enum Delivery {
    Ok(Response),
    Failed(FailReason),
}

/// Typed rejection at `submit` time (the request never entered a shard;
/// no `Delivery` will arrive).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bad payload (wrong image size).
    Malformed(String),
    /// Unknown variant, or no variant satisfies the accuracy class and no
    /// exact fallback is served.
    Unroutable(String),
    /// Load shed: per-variant admission depth or shard ingress full.
    Shed {
        variant: String,
        depth: usize,
        limit: usize,
    },
    /// The server's shards have shut down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Malformed(m) => write!(f, "malformed request: {m}"),
            SubmitError::Unroutable(m) => write!(f, "{m}"),
            SubmitError::Shed {
                variant,
                depth,
                limit,
            } => write!(f, "shed: variant {variant:?} queue depth {depth} >= limit {limit}"),
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// How to stand the server up.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Coordinator shards behind the consistent-hash ring.
    pub shards: usize,
    pub policy: BatchPolicy,
    /// Per-variant admission depth limit (shared across shards) and
    /// per-shard ingress channel capacity.
    pub queue_limit: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            policy: BatchPolicy::default(),
            queue_limit: 4096,
        }
    }
}

/// Handle to a running server.
pub struct InferenceServer {
    shards: Vec<ShardPipeline>,
    ring: HashRing,
    routing: RoutingTable,
    policy: BatchPolicy,
    queue_limit: usize,
    health: Arc<Health>,
    res: Arc<ResilienceRuntime>,
    variant_names: Vec<String>,
    pub metrics: Arc<ServerMetrics>,
    pub admission: Arc<AdmissionController>,
    /// The backend's per-execute batch capacity.
    pub batch: usize,
    /// Backend label ("pjrt" / "native").
    pub backend: &'static str,
    /// Per-family accuracy/energy tables, warm-started from the
    /// design-point store at boot (empty when no store is available).
    pub profiles: BTreeMap<String, VariantProfile>,
}

impl InferenceServer {
    /// Start on the PJRT backend over AOT artifacts (the historical entry
    /// point; equivalent to `start_with_backend(PjrtFactory::…)`).
    pub fn start(store: &ArtifactStore, policy: BatchPolicy) -> Result<InferenceServer> {
        Self::start_with_queue_limit(store, policy, 4096)
    }

    /// PJRT start with an explicit per-variant queue-depth limit.
    pub fn start_with_queue_limit(
        store: &ArtifactStore,
        policy: BatchPolicy,
        queue_limit: usize,
    ) -> Result<InferenceServer> {
        Self::start_with_backend(Arc::new(PjrtFactory::from_artifacts(store)), policy, queue_limit)
    }

    /// Single-shard start (the historical entry point).
    pub fn start_with_backend(
        factory: Arc<dyn BackendFactory>,
        policy: BatchPolicy,
        queue_limit: usize,
    ) -> Result<InferenceServer> {
        Self::start_sharded(
            factory,
            ServerConfig {
                shards: 1,
                policy,
                queue_limit,
            },
        )
    }

    /// Start `cfg.shards` coordinator shards, each running one pipeline
    /// per variant with backends built **on their executor threads** (PJRT
    /// executables are per-thread; the native backend keeps per-worker
    /// scratch). Boot is all-or-nothing: if any of the shards × variants
    /// backends fails to initialize, everything tears down and the call
    /// errors.
    pub fn start_sharded(
        factory: Arc<dyn BackendFactory>,
        cfg: ServerConfig,
    ) -> Result<InferenceServer> {
        Self::start_resilient(factory, cfg, ResilienceConfig::default())
    }

    /// [`Self::start_sharded`] plus the fault-tolerance + elasticity
    /// layer ([`super::resilience`]): circuit breakers, retry/hedging,
    /// the degradation ladder, executor self-healing and autoscaling,
    /// each enabled by its knob in `res_cfg`. The default `res_cfg`
    /// reproduces the legacy pipeline exactly.
    pub fn start_resilient(
        factory: Arc<dyn BackendFactory>,
        cfg: ServerConfig,
        res_cfg: ResilienceConfig,
    ) -> Result<InferenceServer> {
        // Degenerate configs get a clean error instead of undefined
        // behavior (a zero-capacity channel would deadlock the batcher;
        // a zero SLO expires everything before it can batch).
        if cfg.shards == 0 {
            bail!("server config: shards must be >= 1 (got 0)");
        }
        if cfg.queue_limit == 0 {
            bail!("server config: queue_limit must be >= 1 (got 0)");
        }
        if cfg.policy.max_batch == 0 {
            bail!("server config: max_batch must be >= 1 (got 0)");
        }
        if cfg.policy.slo.is_zero() {
            bail!("server config: the server-wide SLO must be positive");
        }
        if let Some(a) = res_cfg.autoscale {
            if a.max_workers == 0 {
                bail!("resilience config: autoscale max_workers must be >= 1 (got 0)");
            }
        }
        let variants = factory.variants();
        if variants.is_empty() {
            bail!("backend factory exposes no variants");
        }
        let n_shards = cfg.shards.max(1);
        let res = Arc::new(ResilienceRuntime::new(res_cfg, &variants, n_shards));
        let metrics = Arc::new(ServerMetrics::new());
        // ONE admission controller across shards keeps the per-variant
        // depth limit a server-wide property, independent of sharding.
        let admission = Arc::new(AdmissionController::new(
            cfg.queue_limit,
            variants.iter().cloned(),
        ));
        let health = Arc::new(Health::default());
        crate::obs::gauge("serve.queue_limit").set(cfg.queue_limit as i64);
        crate::obs::gauge("serve.variants").set(variants.len() as i64);
        crate::obs::gauge("serve.shards").set(n_shards as i64);
        // Executors report backend construction over this channel so boot
        // fails fast instead of "serving" with dead workers (e.g. PJRT
        // behind the offline xla stub, or missing weights).
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let mut shards = Vec::with_capacity(n_shards);
        let mut failure: Option<String> = None;
        for shard in 0..n_shards {
            match spawn_shard(ShardCtx {
                shard,
                factory: Arc::clone(&factory),
                variants: variants.clone(),
                policy: cfg.policy,
                queue_limit: cfg.queue_limit,
                metrics: Arc::clone(&metrics),
                health: Arc::clone(&health),
                res: Arc::clone(&res),
                ready: ready_tx.clone(),
            }) {
                Ok(p) => shards.push(p),
                Err(e) => {
                    failure = Some(format!("{e:#}"));
                    break;
                }
            }
        }
        drop(ready_tx);
        // Block until every spawned executor's backend is up; tear down
        // and error if any cannot initialize (all-or-nothing boot).
        for _ in 0..shards.len() * variants.len() {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    failure.get_or_insert(msg);
                }
                Err(_) => {
                    failure
                        .get_or_insert_with(|| "a worker exited before reporting readiness".into());
                    break;
                }
            }
        }
        if let Some(msg) = failure {
            for s in shards {
                s.shutdown();
            }
            bail!("backend worker failed to initialize: {msg}");
        }
        // Until profiles attach, class routing only knows the exact
        // fallback (when served).
        let routing = RoutingTable::from_profiles(&BTreeMap::new(), &variants);
        Ok(InferenceServer {
            shards,
            ring: HashRing::new(n_shards),
            routing,
            policy: cfg.policy,
            queue_limit: cfg.queue_limit,
            health,
            res,
            variant_names: variants,
            metrics,
            admission,
            batch: factory.max_batch(),
            backend: factory.backend_name(),
            profiles: BTreeMap::new(),
        })
    }

    /// Install warm-started serving tables (see
    /// [`super::warmstart::warm_start_profiles`]) and rebuild the
    /// accuracy-class routing table from them.
    pub fn attach_profiles(&mut self, profiles: BTreeMap<String, VariantProfile>) {
        self.profiles = profiles;
        self.routing = RoutingTable::from_profiles(&self.profiles, &self.variant_names);
    }

    /// The characterization profile behind a serving variant, if the store
    /// held one at boot.
    pub fn profile(&self, variant: &str) -> Option<&VariantProfile> {
        profile_for_variant(&self.profiles, variant)
    }

    /// The accuracy-class routing table currently in force.
    pub fn routing(&self) -> &RoutingTable {
        &self.routing
    }

    /// Route one request into its shard. Typed errors for malformed
    /// payloads, unroutable targets and shed load; `Ok(())` guarantees
    /// exactly one [`Delivery`] on `respond`.
    pub fn submit(&self, req: Request) -> std::result::Result<(), SubmitError> {
        // Reject bad payloads at the door: a malformed image inside a
        // batch would otherwise fail the whole backend execution and
        // drop every batchmate's response with it.
        if req.image.len() != IMAGE_BYTES {
            return Err(SubmitError::Malformed(format!(
                "image has {} bytes, want {IMAGE_BYTES} (16×16 grayscale)",
                req.image.len()
            )));
        }
        let _admit = crate::obs::span("serve.admit");
        let (variant, degraded) = match &req.route {
            Route::Variant(v) => {
                if !self.variant_names.iter().any(|n| n == v) {
                    return Err(SubmitError::Unroutable(format!(
                        "unknown variant {v:?}; have {:?}",
                        self.variant_names
                    )));
                }
                (v.clone(), false)
            }
            Route::Class(class) => {
                crate::obs::counter("serve.route.class_requests").inc();
                // Degradation ladder: skip variants whose breaker is open
                // or whose queue-wait pressure crossed the threshold;
                // the decision is flagged `degraded` when the first
                // choice was skipped. With resilience off the predicate
                // is always true and this is plain `select`.
                match self.routing.select_with(class, |v| self.res.routable(v)) {
                    Some(d) => {
                        if d.fallback {
                            crate::obs::counter("serve.route.fallback_exact").inc();
                        }
                        if d.degraded {
                            crate::obs::counter("serve.degrade.rerouted").inc();
                        }
                        crate::obs::counter(&format!("serve.route.to.{}", d.variant)).inc();
                        (d.variant, d.degraded)
                    }
                    None => {
                        // Only shed when variants satisfying the class
                        // exist but none is currently available — a class
                        // nothing satisfies is unroutable, not shed.
                        if self.routing.select(class).is_some() {
                            crate::obs::counter("serve.degrade.shed_no_candidate").inc();
                            return Err(SubmitError::Shed {
                                variant: format!("class:{}", class.name),
                                depth: 0,
                                limit: 0,
                            });
                        }
                        return Err(SubmitError::Unroutable(format!(
                            "no servable variant satisfies accuracy class {:?} \
                             (max drop {}) and no exact fallback is served",
                            class.name, class.max_drop
                        )));
                    }
                }
            }
        };
        // Probe-consuming breaker admission, exactly once and only for
        // the variant actually being enqueued — routing screened its
        // candidates through the read-only `routable`, so half-open
        // probe slots are never spent on rungs that don't serve. An
        // explicitly-requested variant behind an open breaker (or a
        // class whose pick tripped since the routability check)
        // fast-fails as a shed: there is no class budget to spend on
        // re-routing it elsewhere.
        let epoch = match self.res.admit(&variant) {
            Some(e) => e,
            None => {
                crate::obs::counter("serve.breaker.fast_fail").inc();
                return Err(SubmitError::Shed {
                    variant,
                    depth: 0,
                    limit: 0,
                });
            }
        };
        // Open the trace context once the request is routable: shed
        // requests (admission depth, full ingress) complete as `Shed`
        // timelines; malformed/unroutable rejections never existed as far
        // as the pipeline is concerned.
        let stamps = crate::obs::StageStamps::begin();
        let shard = self.ring.shard_for(HashRing::key_for(&req.image));
        let ticket = match self.admission.admit(&variant) {
            Some(Ok(t)) => t,
            Some(Err(Admission::Shed { depth, limit })) => {
                // The request dies before it can produce a breaker
                // outcome: hand any half-open probe slot back.
                self.res.probe_abort(&variant, epoch);
                complete_shed(stamps, shard as u32, &variant);
                return Err(SubmitError::Shed {
                    variant,
                    depth,
                    limit,
                });
            }
            Some(Err(Admission::Admitted)) | None => {
                self.res.probe_abort(&variant, epoch);
                return Err(SubmitError::Unroutable(format!(
                    "admission state missing for {variant:?}"
                )))
            }
        };
        let now = Instant::now();
        let slo = req.slo.unwrap_or(self.policy.slo);
        let deadline = now + slo;
        // Hedging: when configured and the deadline has enough slack, a
        // bit-identical copy of the request runs on a second shard; the
        // slots share a claim so exactly one delivers (first success
        // wins, the duplicate is discarded in the responder).
        let hedge = match self.res.cfg.hedge_slack {
            Some(th) if self.shards.len() > 1 && slo >= th => {
                let (primary, hedge) = ResponseSlot::hedged_pair(req.respond);
                Some((primary, hedge))
            }
            _ => None,
        };
        let (respond, hedge_slot) = match hedge {
            Some((primary, hedge)) => (primary, Some(hedge)),
            None => (ResponseSlot::direct(req.respond), None),
        };
        let hedge_image = hedge_slot.as_ref().map(|_| req.image.clone());
        let queued = QueuedRequest {
            image: req.image,
            respond,
            enqueued: now,
            deadline,
            stamps,
            degraded,
            breaker_epoch: epoch,
            _ticket: Some(ticket),
        };
        match self.shards[shard].ingress[&variant].try_send(queued) {
            Ok(()) => {
                if let (Some(hslot), Some(image)) = (hedge_slot, hedge_image) {
                    self.issue_hedge(shard, &variant, image, hslot, now, deadline, degraded);
                }
                Ok(())
            }
            Err(TrySendError::Full(dropped)) => {
                // Backpressure past admission (shard ingress at capacity):
                // shed, releasing the ticket and any probe slot. The
                // unissued hedge slot (if any) drops with its claim
                // unexercised.
                self.res.probe_abort(&variant, epoch);
                complete_shed(dropped.stamps, shard as u32, &variant);
                drop(dropped);
                self.admission.note_shed();
                Err(SubmitError::Shed {
                    variant,
                    depth: self.queue_limit,
                    limit: self.queue_limit,
                })
            }
            Err(TrySendError::Disconnected(_)) => {
                self.res.probe_abort(&variant, epoch);
                Err(SubmitError::ShuttingDown)
            }
        }
    }

    /// Best-effort enqueue of the hedged copy on the next shard over. A
    /// bounced hedge (ingress full, shard gone) cancels its slot so the
    /// primary's failure disposition is unaffected — hedging only ever
    /// adds a second chance, never a second failure mode.
    #[allow(clippy::too_many_arguments)]
    fn issue_hedge(
        &self,
        primary_shard: usize,
        variant: &str,
        image: Vec<u8>,
        slot: ResponseSlot,
        enqueued: Instant,
        deadline: Instant,
        degraded: bool,
    ) {
        let shard = (primary_shard + 1) % self.shards.len();
        let queued = QueuedRequest {
            image,
            respond: slot,
            enqueued,
            deadline,
            stamps: crate::obs::StageStamps::default(),
            degraded,
            breaker_epoch: NO_BREAKER_EPOCH,
            _ticket: None,
        };
        match self.shards[shard].ingress[variant].try_send(queued) {
            Ok(()) => crate::obs::counter("serve.hedge.issued").inc(),
            Err(TrySendError::Full(bounced)) | Err(TrySendError::Disconnected(bounced)) => {
                crate::obs::counter("serve.hedge.cancelled").inc();
                // If the primary already failed (its disposition saw
                // this copy outstanding and deferred), the cancel is
                // the last settler: deliver the failure here or the
                // request vanishes from the accounting identity.
                if matches!(bounced.respond.cancel(), FailDisposition::Deliver) {
                    self.metrics.record_failed(1);
                    crate::obs::counter("serve.failed.execute").inc();
                    bounced.respond.send(Delivery::Failed(FailReason::ExecuteFailed(
                        "primary copy failed and its hedge bounced".into(),
                    )));
                }
            }
        }
    }

    /// Blocking convenience: submit to a variant and wait.
    pub fn infer(&self, image: Vec<u8>, variant: &str) -> Result<Response> {
        self.infer_route(image, Route::Variant(variant.to_string()), None)
    }

    /// Blocking convenience over the full wire format.
    pub fn infer_route(
        &self,
        image: Vec<u8>,
        route: Route,
        slo: Option<Duration>,
    ) -> Result<Response> {
        let (tx, rx) = channel();
        self.submit(Request {
            image,
            route,
            slo,
            respond: tx,
        })?;
        match rx.recv().context("worker dropped the response")? {
            Delivery::Ok(resp) => Ok(resp),
            Delivery::Failed(reason) => bail!("request failed: {reason}"),
        }
    }

    pub fn variants(&self) -> Vec<String> {
        self.variant_names.clone()
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// `Some(description)` once any executor has panicked — the server
    /// still answers (failing fast) but must not report a healthy exit.
    pub fn failure(&self) -> Option<String> {
        self.health.failure()
    }

    pub fn healthy(&self) -> bool {
        self.health.healthy()
    }

    /// Re-publish time-derived resilience gauges (breaker open
    /// durations). Call right before telemetry snapshot flushes so
    /// `openacm obs health` can tell a breaker mid-cooldown from one
    /// that has been stuck away from Closed for whole probe cycles.
    pub fn refresh_resilience_gauges(&self) {
        self.res.refresh_gauges();
    }

    /// Graceful shutdown: close every shard's ingress, drain in-flight
    /// batches through execute + respond, then join all stage threads.
    pub fn shutdown(mut self) {
        for s in self.shards.drain(..) {
            s.shutdown();
        }
    }
}

/// Close a shed request's timeline into the tail-sampling collector
/// (failure class — always kept). No-op when untraced.
fn complete_shed(stamps: crate::obs::StageStamps, shard: u32, variant: &str) {
    if stamps.id != 0 {
        crate::obs::trace::collector().complete(stamps.finish(
            shard,
            variant,
            crate::obs::TraceOutcome::Shed,
            crate::obs::trace::now_us(),
        ));
    }
}

// `argmax` comes from `nn::eval` so server responses, workload labels and
// accuracy scoring all share one total-ordering argmax (NaN-safe).
//
// Full server tests live in rust/tests/serving.rs (single-shard native
// soak + PJRT suite) and rust/tests/serving_shard.rs (sharded adversarial
// property suite, million-request soak, panic regression).
