//! The inference server: router + per-variant batcher workers over a
//! pluggable execution [`Backend`] (PJRT graph or the batched native
//! quantized CNN — see `runtime::backend` for the dispatch rules).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{Admission, AdmissionController, Ticket};
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::ServerMetrics;
use super::warmstart::{profile_for_variant, VariantProfile};
use crate::nn::eval::argmax;
use crate::runtime::backend::IMAGE_BYTES;
use crate::runtime::{ArtifactStore, Backend, BackendFactory, PjrtFactory};

/// A classification request: one 16×16 grayscale image + target variant.
pub struct Request {
    pub image: Vec<u8>,
    pub variant: String,
    pub respond: Sender<Response>,
}

/// The response: 10 logits plus the predicted class.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub predicted: usize,
}

struct QueuedRequest {
    image: Vec<u8>,
    respond: Sender<Response>,
    enqueued: Instant,
    /// Admission slot, released when the response is delivered (drop).
    _ticket: Ticket,
}

/// Handle to a running server.
pub struct InferenceServer {
    routes: BTreeMap<String, Sender<QueuedRequest>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
    pub admission: Arc<AdmissionController>,
    /// The backend's per-execute batch capacity.
    pub batch: usize,
    /// Backend label ("pjrt" / "native").
    pub backend: &'static str,
    /// Per-family accuracy/energy tables, warm-started from the
    /// design-point store at boot (empty when no store is available).
    pub profiles: BTreeMap<String, VariantProfile>,
}

impl InferenceServer {
    /// Start on the PJRT backend over AOT artifacts (the historical entry
    /// point; equivalent to `start_with_backend(PjrtFactory::…)`).
    pub fn start(store: &ArtifactStore, policy: BatchPolicy) -> Result<InferenceServer> {
        Self::start_with_queue_limit(store, policy, 4096)
    }

    /// PJRT start with an explicit per-variant queue-depth limit.
    pub fn start_with_queue_limit(
        store: &ArtifactStore,
        policy: BatchPolicy,
        queue_limit: usize,
    ) -> Result<InferenceServer> {
        Self::start_with_backend(Arc::new(PjrtFactory::from_artifacts(store)), policy, queue_limit)
    }

    /// Start one batcher worker per variant, each executing through a
    /// backend built by `factory` **on the worker thread** (PJRT
    /// executables are per-thread; the native backend keeps per-worker
    /// scratch). Submissions beyond `queue_limit` per variant are shed
    /// with an error instead of growing queue latency without bound.
    pub fn start_with_backend(
        factory: Arc<dyn BackendFactory>,
        policy: BatchPolicy,
        queue_limit: usize,
    ) -> Result<InferenceServer> {
        let variants = factory.variants();
        if variants.is_empty() {
            bail!("backend factory exposes no variants");
        }
        let metrics = Arc::new(ServerMetrics::new());
        let admission = Arc::new(AdmissionController::new(
            queue_limit,
            variants.iter().cloned(),
        ));
        crate::obs::gauge("serve.queue_limit").set(queue_limit as i64);
        crate::obs::gauge("serve.variants").set(variants.len() as i64);
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        // Workers report backend construction over this channel so boot
        // fails fast instead of "serving" with dead workers (e.g. PJRT
        // behind the offline xla stub, or missing weights).
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        for variant in &variants {
            let (tx, rx): (Sender<QueuedRequest>, Receiver<QueuedRequest>) = channel();
            routes.insert(variant.clone(), tx);
            let factory = Arc::clone(&factory);
            let variant = variant.clone();
            let metrics = Arc::clone(&metrics);
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("batcher-{variant}"))
                .spawn(move || {
                    let mut backend = match factory.create(&variant) {
                        Ok(b) => {
                            // Boot may already have failed on a sibling;
                            // a closed channel is fine to ignore.
                            let _ = ready.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{variant}: {e:#}")));
                            return;
                        }
                    };
                    // Never drain more than one backend execution's worth.
                    let policy = BatchPolicy {
                        max_batch: policy.max_batch.min(backend.max_batch()).max(1),
                        ..policy
                    };
                    // Per-worker telemetry handles, resolved once: the
                    // in-loop record path is lock-free (obs::registry).
                    let queue_wait = crate::obs::histogram("serve.queue_wait_us");
                    let execute_failures = crate::obs::counter("serve.execute_failures");
                    let delivered = crate::obs::counter("serve.responses_delivered");
                    while let Some(batch) = next_batch(&rx, &policy) {
                        let batch_span = crate::obs::span("serve.batch");
                        let n = batch.len();
                        for q in &batch {
                            queue_wait.record(q.enqueued.elapsed().as_micros() as u64);
                        }
                        let images: Vec<&[u8]> =
                            batch.iter().map(|q| q.image.as_slice()).collect();
                        let rows = {
                            let _execute = crate::obs::span("execute");
                            backend.infer_batch(&images)
                        };
                        let rows = match rows {
                            Ok(r) => r,
                            Err(e) => {
                                crate::obs::error(
                                    "serve",
                                    "execute failed",
                                    &[
                                        ("variant", variant.clone()),
                                        ("error", format!("{e:#}")),
                                    ],
                                );
                                execute_failures.inc();
                                continue;
                            }
                        };
                        if rows.len() != n {
                            crate::obs::error(
                                "serve",
                                "backend returned a short batch",
                                &[
                                    ("variant", variant.clone()),
                                    ("rows", rows.len().to_string()),
                                    ("batch", n.to_string()),
                                ],
                            );
                            execute_failures.inc();
                            continue;
                        }
                        // Record metrics BEFORE completing the requests so a
                        // caller that snapshots right after the last response
                        // sees every batch counted.
                        let lats: Vec<f64> = batch
                            .iter()
                            .map(|q| q.enqueued.elapsed().as_micros() as f64)
                            .collect();
                        metrics.record_batch(n, &lats);
                        {
                            let _respond = crate::obs::span("respond");
                            for (q, logits) in batch.into_iter().zip(rows) {
                                let predicted = argmax(&logits);
                                // Receiver may have gone away; ignore.
                                let _ = q.respond.send(Response { logits, predicted });
                            }
                        }
                        delivered.add(n as u64);
                        drop(batch_span);
                    }
                })
                .context("spawning batcher thread")?;
            workers.push(handle);
        }
        drop(ready_tx);
        // Block until every worker's backend is up; tear down and error
        // if any cannot initialize (all-or-nothing boot).
        for _ in 0..workers.len() {
            let failure = match ready_rx.recv() {
                Ok(Ok(())) => None,
                Ok(Err(msg)) => Some(msg),
                Err(_) => Some("a worker exited before reporting readiness".to_string()),
            };
            if let Some(msg) = failure {
                // Closing the routes ends every worker's request loop.
                routes.clear();
                for w in workers.drain(..) {
                    let _ = w.join();
                }
                bail!("backend worker failed to initialize: {msg}");
            }
        }
        Ok(InferenceServer {
            routes,
            workers,
            metrics,
            admission,
            batch: factory.max_batch(),
            backend: factory.backend_name(),
            profiles: BTreeMap::new(),
        })
    }

    /// Install warm-started serving tables (see
    /// [`super::warmstart::warm_start_profiles`]).
    pub fn attach_profiles(&mut self, profiles: BTreeMap<String, VariantProfile>) {
        self.profiles = profiles;
    }

    /// The characterization profile behind a serving variant, if the store
    /// held one at boot.
    pub fn profile(&self, variant: &str) -> Option<&VariantProfile> {
        profile_for_variant(&self.profiles, variant)
    }

    /// Route one request. Errors on malformed images, unknown variants
    /// and on shed load (queue depth above the admission limit).
    pub fn submit(&self, req: Request) -> Result<()> {
        // Reject bad payloads at the door: a malformed image inside a
        // batch would otherwise fail the whole backend execution and
        // drop every batchmate's response with it.
        if req.image.len() != IMAGE_BYTES {
            bail!(
                "image has {} bytes, want {IMAGE_BYTES} (16×16 grayscale)",
                req.image.len()
            );
        }
        let _admit = crate::obs::span("serve.admit");
        let route = match self.routes.get(&req.variant) {
            Some(r) => r,
            None => bail!(
                "unknown variant {:?}; have {:?}",
                req.variant,
                self.routes.keys().collect::<Vec<_>>()
            ),
        };
        let ticket = match self.admission.admit(&req.variant) {
            Some(Ok(t)) => t,
            Some(Err(Admission::Shed { depth, limit })) => {
                bail!("shed: variant {:?} queue depth {depth} >= limit {limit}", req.variant)
            }
            Some(Err(Admission::Admitted)) | None => {
                bail!("admission state missing for {:?}", req.variant)
            }
        };
        route
            .send(QueuedRequest {
                image: req.image,
                respond: req.respond,
                enqueued: Instant::now(),
                _ticket: ticket,
            })
            .map_err(|_| anyhow::anyhow!("variant worker has shut down"))?;
        Ok(())
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, image: Vec<u8>, variant: &str) -> Result<Response> {
        let (tx, rx) = channel();
        self.submit(Request {
            image,
            variant: variant.to_string(),
            respond: tx,
        })?;
        rx.recv().context("worker dropped the response")
    }

    pub fn variants(&self) -> Vec<String> {
        self.routes.keys().cloned().collect()
    }

    /// Shut down: close all routes and join workers.
    pub fn shutdown(mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// `argmax` comes from `nn::eval` so server responses, workload labels and
// accuracy scoring all share one total-ordering argmax (NaN-safe).
//
// Full server tests live in rust/tests/serving.rs: the native-backend
// soak suite runs everywhere; the PJRT suite needs artifacts.
