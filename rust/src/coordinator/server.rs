//! The inference server: router + per-variant batcher workers over the
//! PJRT executable.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{Admission, AdmissionController, Ticket};
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::ServerMetrics;
use super::warmstart::{profile_for_variant, VariantProfile};
use crate::runtime::{client, ArtifactStore, Runtime};

/// A classification request: one 16×16 grayscale image + target variant.
pub struct Request {
    pub image: Vec<u8>,
    pub variant: String,
    pub respond: Sender<Response>,
}

/// The response: 10 logits plus the predicted class.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub predicted: usize,
}

struct QueuedRequest {
    image: Vec<u8>,
    respond: Sender<Response>,
    enqueued: Instant,
    /// Admission slot, released when the response is delivered (drop).
    _ticket: Ticket,
}

/// Handle to a running server.
pub struct InferenceServer {
    routes: BTreeMap<String, Sender<QueuedRequest>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
    pub admission: Arc<AdmissionController>,
    pub batch: usize,
    /// Per-family accuracy/energy tables, warm-started from the
    /// design-point store at boot (empty when no store is available).
    pub profiles: BTreeMap<String, VariantProfile>,
}

impl InferenceServer {
    /// Start: compile the model once per variant worker (each worker owns
    /// its executable — PJRT executables are not shared across threads)
    /// and spawn one batcher thread per LUT variant.
    pub fn start(store: &ArtifactStore, policy: BatchPolicy) -> Result<InferenceServer> {
        Self::start_with_queue_limit(store, policy, 4096)
    }

    /// Start with an explicit per-variant queue-depth limit (admission
    /// control / backpressure): submissions beyond the limit are shed with
    /// an error instead of growing queue latency without bound.
    pub fn start_with_queue_limit(
        store: &ArtifactStore,
        policy: BatchPolicy,
        queue_limit: usize,
    ) -> Result<InferenceServer> {
        let metrics = Arc::new(ServerMetrics::new());
        let admission = Arc::new(AdmissionController::new(
            queue_limit,
            store.luts.keys().cloned(),
        ));
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        let b = store.batch;
        for (variant, lut) in &store.luts {
            let (tx, rx): (Sender<QueuedRequest>, Receiver<QueuedRequest>) = channel();
            routes.insert(variant.clone(), tx);
            let lut = lut.clone();
            let hlo = store.model_hlo.clone();
            let weights = store.weights.clone();
            let metrics = Arc::clone(&metrics);
            let policy = BatchPolicy {
                max_batch: policy.max_batch.min(b),
                ..policy
            };
            let handle = std::thread::Builder::new()
                .name(format!("batcher-{variant}"))
                .spawn(move || {
                    // Each worker compiles its own executable.
                    let rt = match Runtime::cpu() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("worker init failed: {e:#}");
                            return;
                        }
                    };
                    let model = match rt.compile_hlo_text(&hlo) {
                        Ok(m) => m,
                        Err(e) => {
                            eprintln!("compile failed: {e:#}");
                            return;
                        }
                    };
                    let lut_lit = match client::literal_i32(&[65536], &lut) {
                        Ok(l) => l,
                        Err(e) => {
                            eprintln!("lut literal failed: {e:#}");
                            return;
                        }
                    };
                    let weight_lits = match client::weight_literals(&weights) {
                        Ok(w) => w,
                        Err(e) => {
                            eprintln!("weight literals failed: {e:#}");
                            return;
                        }
                    };
                    while let Some(batch) = next_batch(&rx, &policy) {
                        let n = batch.len();
                        // Pad to the static batch size.
                        let mut px = vec![0i32; b * 256];
                        for (j, q) in batch.iter().enumerate() {
                            for (k, &p) in q.image.iter().enumerate() {
                                px[j * 256 + k] = p as i32;
                            }
                        }
                        let img = match client::literal_i32(&[b, 16, 16], &px) {
                            Ok(l) => l,
                            Err(e) => {
                                eprintln!("image literal failed: {e:#}");
                                continue;
                            }
                        };
                        let mut args = vec![img, lut_lit.clone()];
                        args.extend(weight_lits.iter().cloned());
                        let out = match model.run_f32(&args, b * 10) {
                            Ok(o) => o,
                            Err(e) => {
                                eprintln!("execute failed: {e:#}");
                                continue;
                            }
                        };
                        // Record metrics BEFORE completing the requests so a
                        // caller that snapshots right after the last response
                        // sees every batch counted.
                        let lats: Vec<f64> = batch
                            .iter()
                            .map(|q| q.enqueued.elapsed().as_micros() as f64)
                            .collect();
                        metrics.record_batch(n, &lats);
                        for (j, q) in batch.into_iter().enumerate() {
                            let logits = out[j * 10..(j + 1) * 10].to_vec();
                            let predicted = argmax(&logits);
                            // Receiver may have gone away; ignore.
                            let _ = q.respond.send(Response { logits, predicted });
                        }
                    }
                })
                .context("spawning batcher thread")?;
            workers.push(handle);
        }
        Ok(InferenceServer {
            routes,
            workers,
            metrics,
            admission,
            batch: b,
            profiles: BTreeMap::new(),
        })
    }

    /// Install warm-started serving tables (see
    /// [`super::warmstart::warm_start_profiles`]).
    pub fn attach_profiles(&mut self, profiles: BTreeMap<String, VariantProfile>) {
        self.profiles = profiles;
    }

    /// The characterization profile behind a serving variant, if the store
    /// held one at boot.
    pub fn profile(&self, variant: &str) -> Option<&VariantProfile> {
        profile_for_variant(&self.profiles, variant)
    }

    /// Route one request. Errors on unknown variants and on shed load
    /// (queue depth above the admission limit).
    pub fn submit(&self, req: Request) -> Result<()> {
        let route = match self.routes.get(&req.variant) {
            Some(r) => r,
            None => bail!(
                "unknown variant {:?}; have {:?}",
                req.variant,
                self.routes.keys().collect::<Vec<_>>()
            ),
        };
        let ticket = match self.admission.admit(&req.variant) {
            Some(Ok(t)) => t,
            Some(Err(Admission::Shed { depth, limit })) => {
                bail!("shed: variant {:?} queue depth {depth} >= limit {limit}", req.variant)
            }
            Some(Err(Admission::Admitted)) | None => {
                bail!("admission state missing for {:?}", req.variant)
            }
        };
        route
            .send(QueuedRequest {
                image: req.image,
                respond: req.respond,
                enqueued: Instant::now(),
                _ticket: ticket,
            })
            .map_err(|_| anyhow::anyhow!("variant worker has shut down"))?;
        Ok(())
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, image: Vec<u8>, variant: &str) -> Result<Response> {
        let (tx, rx) = channel();
        self.submit(Request {
            image,
            variant: variant.to_string(),
            respond: tx,
        })?;
        rx.recv().context("worker dropped the response")
    }

    pub fn variants(&self) -> Vec<String> {
        self.routes.keys().cloned().collect()
    }

    /// Shut down: close all routes and join workers.
    pub fn shutdown(mut self) {
        self.routes.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }
    // Full server tests live in rust/tests/serving.rs (they need artifacts).
}
