//! Per-shard serving pipeline: admission → batching → execute → respond
//! as decoupled stages over **bounded** channels.
//!
//! ```text
//!  submit ──try_send──▶ ingress (cap = queue_limit, per variant)
//!                          │  batcher thread: deadline-bucket next_batch
//!                          ▼
//!                       execute queue (cap = 2 batches)
//!                          │  executor thread: owns the Backend,
//!                          │  catch_unwind around infer_batch
//!                          ▼
//!                       finished queue (cap = 8, shared per shard)
//!                          │  responder thread: metrics + delivery
//!                          ▼
//!                       respond channels (one per request)
//! ```
//!
//! Every stage boundary is a `sync_channel`, so overload turns into
//! backpressure and ultimately a shed at `submit` (`try_send` Full) —
//! never an unbounded queue. Shutdown is a channel-close cascade: dropping
//! the ingress senders lets the batcher drain what is already queued, the
//! executor finishes the batches in flight, and the responder delivers
//! everything before its receiver disconnects — in-flight work is drained,
//! not dropped.
//!
//! Failure is a first-class outcome: a deadline that expires in queue, a
//! backend error, or a worker panic each produce a [`Delivery::Failed`]
//! for every affected request (exactly one delivery per admitted request,
//! which is what makes `submitted == delivered + shed + failed` hold). A
//! panic additionally poisons the executor — subsequent batches fail fast
//! instead of re-entering a possibly corrupt backend — and reports to
//! [`Health`], which `openacm serve` maps to a non-zero exit.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::Ticket;
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::ServerMetrics;
use super::server::{Delivery, FailReason, Response};
use crate::nn::eval::argmax;
use crate::obs::{StageStamps, TraceOutcome};
use crate::runtime::{Backend, BackendFactory};

/// A request admitted into a shard: payload + delivery channel + the
/// deadline the batcher buckets on. The admission [`Ticket`] rides along
/// and releases its slot when the request leaves the pipeline (drop); the
/// [`StageStamps`] trace context is stamped at each stage boundary and
/// closed into the tail-sampling collector at delivery.
pub(crate) struct QueuedRequest {
    pub image: Vec<u8>,
    pub respond: Sender<Delivery>,
    pub enqueued: Instant,
    pub deadline: Instant,
    pub stamps: StageStamps,
    pub _ticket: Ticket,
}

/// A batch leaving the execute stage, bound for the responder.
enum Finished {
    Executed {
        variant: String,
        batch: Vec<QueuedRequest>,
        rows: Vec<Vec<f32>>,
    },
    Failed {
        variant: String,
        batch: Vec<QueuedRequest>,
        reason: FailReason,
    },
}

type FinishedTx = SyncSender<Finished>;

/// Worker-failure flag shared by every executor of a server. First
/// failure wins; `openacm serve` checks it after the drive loop and exits
/// non-zero — a panicked worker must never look like a healthy run.
#[derive(Debug, Default)]
pub struct Health {
    failure: Mutex<Option<String>>,
}

impl Health {
    pub fn report(&self, msg: impl Into<String>) {
        let mut slot = match self.failure.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if slot.is_none() {
            *slot = Some(msg.into());
        }
    }

    pub fn failure(&self) -> Option<String> {
        match self.failure.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }

    pub fn healthy(&self) -> bool {
        self.failure().is_none()
    }
}

/// Everything one shard needs to stand up its stage threads.
pub(crate) struct ShardCtx {
    pub shard: usize,
    pub factory: Arc<dyn BackendFactory>,
    pub variants: Vec<String>,
    pub policy: BatchPolicy,
    pub queue_limit: usize,
    pub metrics: Arc<ServerMetrics>,
    pub health: Arc<Health>,
    /// Backend-construction reports (one per variant) so the server can
    /// boot all-or-nothing.
    pub ready: Sender<std::result::Result<(), String>>,
}

/// One shard's running stages: the per-variant ingress senders plus every
/// stage thread, joined on shutdown.
pub(crate) struct ShardPipeline {
    pub ingress: BTreeMap<String, SyncSender<QueuedRequest>>,
    threads: Vec<JoinHandle<()>>,
}

impl ShardPipeline {
    /// Graceful shutdown: close the ingress, let the close cascade drain
    /// every stage, then join.
    pub fn shutdown(mut self) {
        self.ingress.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Batches in flight between a batcher and its executor: enough to keep
/// the executor busy while the next batch forms, small enough that
/// backpressure reaches the ingress quickly.
const EXEC_QUEUE_BATCHES: usize = 2;
/// Finished batches queued for a shard's responder.
const FINISHED_QUEUE_BATCHES: usize = 8;

pub(crate) fn spawn_shard(ctx: ShardCtx) -> Result<ShardPipeline> {
    let (fin_tx, fin_rx) = sync_channel::<Finished>(FINISHED_QUEUE_BATCHES);
    let mut ingress = BTreeMap::new();
    let mut threads = Vec::new();
    for variant in &ctx.variants {
        let (in_tx, in_rx) = sync_channel::<QueuedRequest>(ctx.queue_limit.max(1));
        ingress.insert(variant.clone(), in_tx);
        let (ex_tx, ex_rx) = sync_channel::<Vec<QueuedRequest>>(EXEC_QUEUE_BATCHES);
        // Never form more than one backend execution's worth.
        let policy = BatchPolicy {
            max_batch: ctx.policy.max_batch.min(ctx.factory.max_batch()).max(1),
            ..ctx.policy
        };
        threads.push(spawn_batcher(
            ctx.shard,
            variant.clone(),
            in_rx,
            ex_tx,
            fin_tx.clone(),
            policy,
        )?);
        threads.push(spawn_executor(
            &ctx,
            variant.clone(),
            ex_rx,
            fin_tx.clone(),
        )?);
    }
    // The responder must see disconnect once batchers + executors exit.
    drop(fin_tx);
    threads.push(spawn_responder(
        ctx.shard,
        fin_rx,
        Arc::clone(&ctx.metrics),
    )?);
    Ok(ShardPipeline { ingress, threads })
}

/// Stage 2: deadline-bucket batching. Pulls from the bounded ingress,
/// closes batches per [`next_batch`]'s SLO rules, fails what already
/// expired in queue, and hands live batches to the executor (blocking —
/// that is the backpressure).
fn spawn_batcher(
    shard: usize,
    variant: String,
    rx: Receiver<QueuedRequest>,
    exec: SyncSender<Vec<QueuedRequest>>,
    finished: FinishedTx,
    policy: BatchPolicy,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("batch-{shard}-{variant}"))
        .spawn(move || {
            let queue_wait = crate::obs::histogram("serve.queue_wait_us");
            let slack = crate::obs::histogram("serve.deadline_slack_us");
            let expired = crate::obs::counter("serve.deadline_expired");
            while let Some(batch) = next_batch(&rx, &policy, |q: &QueuedRequest| q.deadline) {
                // Explicit full path: the executor (a different thread)
                // parents its span under this one via
                // `span_path("serve.batch/execute")`.
                let _batch_span = crate::obs::span_path("serve.batch");
                let t_batch = if crate::obs::trace_enabled() {
                    crate::obs::trace::now_us()
                } else {
                    0
                };
                let now = Instant::now();
                let mut live = Vec::with_capacity(batch.len());
                let mut dead = Vec::new();
                for mut q in batch {
                    queue_wait.record(q.enqueued.elapsed().as_micros() as u64);
                    if q.deadline <= now {
                        dead.push(q);
                    } else {
                        slack.record(q.deadline.saturating_duration_since(now).as_micros() as u64);
                        q.stamps.stamp_batch(t_batch);
                        live.push(q);
                    }
                }
                if !dead.is_empty() {
                    expired.add(dead.len() as u64);
                    forward(
                        &finished,
                        shard as u32,
                        Finished::Failed {
                            variant: variant.clone(),
                            batch: dead,
                            reason: FailReason::DeadlineExpired,
                        },
                    );
                }
                if live.is_empty() {
                    continue;
                }
                if let Err(err) = exec.send(live) {
                    // Executor gone (failed boot / poisoned shutdown):
                    // the batch must still be delivered, as failures.
                    forward(
                        &finished,
                        shard as u32,
                        Finished::Failed {
                            variant: variant.clone(),
                            batch: err.0,
                            reason: FailReason::WorkerPanicked,
                        },
                    );
                }
            }
        })
        .context("spawning batcher thread")
}

/// Stage 3: execution. Owns the backend (built on this thread — PJRT
/// executables are per-thread, the native backend keeps per-worker
/// scratch); every `infer_batch` runs under `catch_unwind`, so a panic
/// fails the batch and poisons the worker instead of hanging the server.
fn spawn_executor(
    ctx: &ShardCtx,
    variant: String,
    rx: Receiver<Vec<QueuedRequest>>,
    finished: FinishedTx,
) -> Result<JoinHandle<()>> {
    let factory = Arc::clone(&ctx.factory);
    let health = Arc::clone(&ctx.health);
    let ready = ctx.ready.clone();
    let shard = ctx.shard;
    std::thread::Builder::new()
        .name(format!("exec-{shard}-{variant}"))
        .spawn(move || {
            let mut backend: Box<dyn Backend> = match factory.create(&variant) {
                Ok(b) => {
                    // Boot may already have failed on a sibling; a closed
                    // channel is fine to ignore.
                    let _ = ready.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready.send(Err(format!("{variant}: {e:#}")));
                    return;
                }
            };
            drop(ready);
            let execute_failures = crate::obs::counter("serve.execute_failures");
            let mut poisoned = false;
            while let Ok(mut batch) = rx.recv() {
                if poisoned {
                    forward(
                        &finished,
                        shard as u32,
                        Finished::Failed {
                            variant: variant.clone(),
                            batch,
                            reason: FailReason::WorkerPanicked,
                        },
                    );
                    continue;
                }
                let traced = crate::obs::trace_enabled();
                let t_exec_start = if traced { crate::obs::trace::now_us() } else { 0 };
                let result = {
                    // Full-path span: this thread's TLS stack is empty, but
                    // the batch stage semantically parents execution.
                    let _execute = crate::obs::span_path("serve.batch/execute");
                    let images: Vec<&[u8]> = batch.iter().map(|q| q.image.as_slice()).collect();
                    catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&images)))
                };
                if traced {
                    let t_exec_end = crate::obs::trace::now_us();
                    for q in &mut batch {
                        q.stamps.stamp_exec(t_exec_start, t_exec_end);
                    }
                }
                let msg = match result {
                    Ok(Ok(rows)) if rows.len() == batch.len() => Finished::Executed {
                        variant: variant.clone(),
                        batch,
                        rows,
                    },
                    Ok(Ok(rows)) => {
                        crate::obs::error(
                            "serve",
                            "backend returned a short batch",
                            &[
                                ("variant", variant.clone()),
                                ("rows", rows.len().to_string()),
                                ("batch", batch.len().to_string()),
                            ],
                        );
                        execute_failures.inc();
                        Finished::Failed {
                            variant: variant.clone(),
                            reason: FailReason::ExecuteFailed(format!(
                                "backend returned {} rows for a batch of {}",
                                rows.len(),
                                batch.len()
                            )),
                            batch,
                        }
                    }
                    Ok(Err(e)) => {
                        crate::obs::error(
                            "serve",
                            "execute failed",
                            &[("variant", variant.clone()), ("error", format!("{e:#}"))],
                        );
                        execute_failures.inc();
                        Finished::Failed {
                            variant: variant.clone(),
                            batch,
                            reason: FailReason::ExecuteFailed(format!("{e:#}")),
                        }
                    }
                    Err(panic) => {
                        let what = panic_message(panic.as_ref());
                        crate::obs::error(
                            "serve",
                            "worker panicked during execute",
                            &[
                                ("shard", shard.to_string()),
                                ("variant", variant.clone()),
                                ("panic", what.clone()),
                            ],
                        );
                        execute_failures.inc();
                        health.report(format!(
                            "shard {shard} variant {variant} worker panicked: {what}"
                        ));
                        poisoned = true;
                        Finished::Failed {
                            variant: variant.clone(),
                            batch,
                            reason: FailReason::WorkerPanicked,
                        }
                    }
                };
                forward(&finished, shard as u32, msg);
            }
        })
        .context("spawning executor thread")
}

/// Stage 4: the shard's single responder — metrics, delivery counters and
/// the per-request `Delivery` sends, off the executor's critical path.
fn spawn_responder(
    shard: usize,
    rx: Receiver<Finished>,
    metrics: Arc<ServerMetrics>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("respond-{shard}"))
        .spawn(move || {
            let shard_delivered = crate::obs::counter(&format!("serve.shard{shard}.delivered"));
            let shard_failed = crate::obs::counter(&format!("serve.shard{shard}.failed"));
            let delivered = crate::obs::counter("serve.responses_delivered");
            let delivered_late = crate::obs::counter("serve.delivered_late");
            let fail_expired = crate::obs::counter("serve.failed.deadline_expired");
            let fail_execute = crate::obs::counter("serve.failed.execute");
            let fail_panic = crate::obs::counter("serve.failed.worker_panic");
            while let Ok(msg) = rx.recv() {
                let _respond = crate::obs::span("respond");
                match msg {
                    Finished::Executed {
                        variant,
                        batch,
                        rows,
                    } => {
                        // Record metrics BEFORE completing the requests so
                        // a caller that snapshots right after the last
                        // response sees every batch counted. Latencies
                        // carry the trace id as a histogram exemplar —
                        // `obs health` links p99 to a concrete request.
                        let lats: Vec<(f64, u64)> = batch
                            .iter()
                            .map(|q| (q.enqueued.elapsed().as_micros() as f64, q.stamps.id))
                            .collect();
                        metrics.record_batch_exemplars(batch.len(), &lats);
                        delivered.add(batch.len() as u64);
                        shard_delivered.add(batch.len() as u64);
                        // Deliveries that landed past their deadline feed
                        // the latency SLO objective.
                        let now = Instant::now();
                        let late = batch.iter().filter(|q| now > q.deadline).count();
                        if late > 0 {
                            delivered_late.add(late as u64);
                        }
                        deliver_rows(shard as u32, variant, batch, rows);
                    }
                    Finished::Failed {
                        variant,
                        batch,
                        reason,
                    } => {
                        let n = batch.len() as u64;
                        metrics.record_failed(batch.len());
                        shard_failed.add(n);
                        match &reason {
                            FailReason::DeadlineExpired => fail_expired.add(n),
                            FailReason::ExecuteFailed(_) => fail_execute.add(n),
                            FailReason::WorkerPanicked => fail_panic.add(n),
                        }
                        fail_batch(shard as u32, &variant, batch, reason);
                    }
                }
            }
        })
        .context("spawning responder thread")
}

/// Hand a finished batch to the responder; if the responder is already
/// gone (shutdown tail, boot teardown), deliver directly — an admitted
/// request gets exactly one delivery (and one trace completion) on every
/// path.
fn forward(finished: &FinishedTx, shard: u32, msg: Finished) {
    if let Err(err) = finished.send(msg) {
        match err.0 {
            Finished::Executed {
                variant,
                batch,
                rows,
            } => deliver_rows(shard, variant, batch, rows),
            Finished::Failed {
                variant,
                batch,
                reason,
            } => fail_batch(shard, &variant, batch, reason),
        }
    }
}

/// Current µs timestamp for trace completion, free when tracing is off.
fn trace_now() -> u64 {
    if crate::obs::trace_enabled() {
        crate::obs::trace::now_us()
    } else {
        0
    }
}

fn deliver_rows(shard: u32, variant: String, batch: Vec<QueuedRequest>, rows: Vec<Vec<f32>>) {
    let t_done = trace_now();
    for (q, logits) in batch.into_iter().zip(rows) {
        if q.stamps.id != 0 {
            crate::obs::trace::collector().complete(q.stamps.finish(
                shard,
                &variant,
                TraceOutcome::Delivered,
                t_done,
            ));
        }
        let predicted = argmax(&logits);
        // Receiver may have gone away; ignore.
        let _ = q.respond.send(Delivery::Ok(Response {
            logits,
            predicted,
            variant: variant.clone(),
        }));
    }
}

/// Deliver a failure to every request in the batch, closing each trace
/// with the outcome matching the [`FailReason`].
fn fail_batch(shard: u32, variant: &str, batch: Vec<QueuedRequest>, reason: FailReason) {
    let outcome = match &reason {
        FailReason::DeadlineExpired => TraceOutcome::DeadlineExpired,
        FailReason::ExecuteFailed(_) => TraceOutcome::ExecuteFailed,
        FailReason::WorkerPanicked => TraceOutcome::WorkerPanicked,
    };
    let t_done = trace_now();
    for q in batch {
        if q.stamps.id != 0 {
            crate::obs::trace::collector().complete(q.stamps.finish(
                shard,
                variant,
                outcome,
                t_done,
            ));
        }
        let _ = q.respond.send(Delivery::Failed(reason.clone()));
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
