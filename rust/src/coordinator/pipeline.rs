//! Per-shard serving pipeline: admission → batching → execute → respond
//! as decoupled stages over **bounded** channels.
//!
//! ```text
//!  submit ──try_send──▶ ingress (cap = queue_limit, per variant)
//!                          │  batcher thread: deadline-bucket next_batch
//!                          ▼
//!                       execute queue (cap = 2 batches)
//!                          │  executor pool: 1..=N workers, each owns a
//!                          │  Backend, catch_unwind around infer_batch
//!                          ▼
//!                       finished queue (cap = 8, shared per shard)
//!                          │  responder thread: metrics + delivery
//!                          ▼
//!                       respond channels (one per request)
//! ```
//!
//! Every stage boundary is a `sync_channel`, so overload turns into
//! backpressure and ultimately a shed at `submit` (`try_send` Full) —
//! never an unbounded queue. Shutdown is a channel-close cascade: dropping
//! the ingress senders lets the batcher drain what is already queued, the
//! executor finishes the batches in flight, and the responder delivers
//! everything before its receiver disconnects — in-flight work is drained,
//! not dropped.
//!
//! Failure is a first-class outcome: a deadline that expires in queue, a
//! backend error, or a worker panic each produce a [`Delivery::Failed`]
//! for every affected request (exactly one delivery per admitted request,
//! which is what makes `submitted == delivered + shed + failed` hold).
//!
//! The resilience layer ([`super::resilience`]) hooks in at three points,
//! all disabled under [`super::resilience::ResilienceConfig::default`]:
//!
//! * **execute**: transient failures retry with backoff on the same
//!   worker; a panic can respawn the backend under a bounded
//!   [`super::resilience::RestartBudget`] instead of poisoning the
//!   worker. With the budget exhausted (or at the default budget of 0)
//!   the legacy behavior holds: the worker poisons itself, fails
//!   subsequent batches fast, and reports to [`Health`] so `openacm
//!   serve` exits non-zero.
//! * **executor pool**: when autoscaling is on, a per-shard×variant
//!   controller watches the queue-wait pressure EMA and grows/shrinks
//!   the worker count within `1..=max_workers`; workers share the
//!   execute queue behind a mutex.
//! * **respond**: every request carries a [`ResponseSlot`]; hedged
//!   requests share claim state between two pipeline copies so exactly
//!   one delivery wins (first success) and the duplicate is discarded
//!   and counted — bit-identical results make the winner
//!   indistinguishable from the loser.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::Ticket;
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::ServerMetrics;
use super::resilience::{autoscale_decision, AutoscalePolicy, ResilienceRuntime, RestartBudget};
use super::server::{Delivery, FailReason, Response};
use crate::nn::eval::argmax;
use crate::obs::{StageStamps, TraceOutcome};
use crate::runtime::{Backend, BackendFactory};

/// How one copy of a request should settle a failed execution.
pub(crate) enum FailDisposition {
    /// Only copy (or last copy, nothing claimed): deliver the failure.
    Deliver,
    /// A sibling copy is still in flight and will settle the request.
    Pending,
    /// A sibling already delivered success: drop this failure silently.
    Discard,
}

/// Shared claim state between the two pipeline copies of a hedged
/// request. `claimed` makes success delivery exactly-once; `outstanding`
/// lets the last failing copy know it must deliver the failure.
pub(crate) struct HedgeState {
    claimed: AtomicBool,
    outstanding: AtomicUsize,
}

/// A request's delivery endpoint. Direct requests have one copy; hedged
/// requests have two copies sharing a [`HedgeState`]. All claim logic
/// lives here so the responder stays a straight-line partition.
pub(crate) struct ResponseSlot {
    tx: Sender<Delivery>,
    hedge: Option<Arc<HedgeState>>,
}

impl ResponseSlot {
    pub fn direct(tx: Sender<Delivery>) -> ResponseSlot {
        ResponseSlot { tx, hedge: None }
    }

    /// Two slots sharing claim state: the primary (traced, ticketed)
    /// and the hedge copy.
    pub fn hedged_pair(tx: Sender<Delivery>) -> (ResponseSlot, ResponseSlot) {
        let state = Arc::new(HedgeState {
            claimed: AtomicBool::new(false),
            outstanding: AtomicUsize::new(2),
        });
        (
            ResponseSlot {
                tx: tx.clone(),
                hedge: Some(Arc::clone(&state)),
            },
            ResponseSlot {
                tx,
                hedge: Some(state),
            },
        )
    }

    /// Claim the success delivery. True exactly once across all copies
    /// of a request; a false return means a sibling already delivered
    /// and this copy's result must be discarded.
    pub fn claim_ok(&self) -> bool {
        match &self.hedge {
            None => true,
            Some(h) => {
                let duplicate = h.claimed.swap(true, Ordering::SeqCst);
                h.outstanding.fetch_sub(1, Ordering::SeqCst);
                !duplicate
            }
        }
    }

    /// Settle a failed execution for this copy. The decrement-then-read
    /// order pairs with `claim_ok`'s swap-then-decrement: if this copy
    /// observes `outstanding == 1` the sibling has fully settled, so
    /// reading `claimed` afterwards cannot race.
    pub fn fail_disposition(&self) -> FailDisposition {
        match &self.hedge {
            None => FailDisposition::Deliver,
            Some(h) => {
                let prev = h.outstanding.fetch_sub(1, Ordering::SeqCst);
                if prev > 1 {
                    FailDisposition::Pending
                } else if h.claimed.load(Ordering::SeqCst) {
                    FailDisposition::Discard
                } else {
                    FailDisposition::Deliver
                }
            }
        }
    }

    /// Forget a copy that never entered the pipeline (the hedge enqueue
    /// bounced off a full or closed ingress). Settlement-aware: if the
    /// sibling already failed and deferred to this copy (its
    /// `fail_disposition` saw us outstanding and returned `Pending`),
    /// the cancel is the last settler and must deliver the failure —
    /// otherwise the client's channel disconnects with no `Delivery`
    /// and the accounting identity loses a request.
    pub fn cancel(&self) -> FailDisposition {
        match &self.hedge {
            // Direct slots are never hedged copies; nothing to settle.
            None => FailDisposition::Discard,
            Some(h) => {
                let prev = h.outstanding.fetch_sub(1, Ordering::SeqCst);
                if prev > 1 {
                    FailDisposition::Pending
                } else if h.claimed.load(Ordering::SeqCst) {
                    FailDisposition::Discard
                } else {
                    FailDisposition::Deliver
                }
            }
        }
    }

    /// Receiver may have gone away; ignore.
    pub fn send(&self, delivery: Delivery) {
        let _ = self.tx.send(delivery);
    }
}

/// A request admitted into a shard: payload + delivery slot + the
/// deadline the batcher buckets on. The admission [`Ticket`] rides along
/// on the primary copy and releases its slot when the request leaves the
/// pipeline (drop); a hedge copy carries no ticket (it borrowed no
/// admission slot) and untraced stamps (id 0), so the primary owns the
/// request's single trace completion. `degraded` marks a class-routed
/// request that the degradation ladder re-routed off its first-choice
/// variant; it is surfaced on the delivered [`Response`].
pub(crate) struct QueuedRequest {
    pub image: Vec<u8>,
    pub respond: ResponseSlot,
    pub enqueued: Instant,
    pub deadline: Instant,
    pub stamps: StageStamps,
    pub degraded: bool,
    /// Breaker admission epoch (`NO_BREAKER_EPOCH` without a breaker,
    /// and on hedge copies — they borrowed no probe slot): matches this
    /// request's outcome to the breaker state that admitted it, so a
    /// half-open probe verdict can't come from a pre-trip batch.
    pub breaker_epoch: u64,
    pub _ticket: Option<Ticket>,
}

/// A batch leaving the execute stage, bound for the responder.
enum Finished {
    Executed {
        variant: String,
        batch: Vec<QueuedRequest>,
        rows: Vec<Vec<f32>>,
    },
    Failed {
        variant: String,
        batch: Vec<QueuedRequest>,
        reason: FailReason,
    },
}

type FinishedTx = SyncSender<Finished>;

/// Worker-failure flag shared by every executor of a server. First
/// failure wins; `openacm serve` checks it after the drive loop and exits
/// non-zero — a panicked worker must never look like a healthy run.
#[derive(Debug, Default)]
pub struct Health {
    failure: Mutex<Option<String>>,
}

impl Health {
    pub fn report(&self, msg: impl Into<String>) {
        let mut slot = match self.failure.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if slot.is_none() {
            *slot = Some(msg.into());
        }
    }

    pub fn failure(&self) -> Option<String> {
        match self.failure.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }

    pub fn healthy(&self) -> bool {
        self.failure().is_none()
    }
}

/// Everything one shard needs to stand up its stage threads.
pub(crate) struct ShardCtx {
    pub shard: usize,
    pub factory: Arc<dyn BackendFactory>,
    pub variants: Vec<String>,
    pub policy: BatchPolicy,
    pub queue_limit: usize,
    pub metrics: Arc<ServerMetrics>,
    pub health: Arc<Health>,
    pub res: Arc<ResilienceRuntime>,
    /// Backend-construction reports (one per variant) so the server can
    /// boot all-or-nothing.
    pub ready: Sender<std::result::Result<(), String>>,
}

/// One shard's running stages: the per-variant ingress senders plus every
/// stage thread, joined on shutdown.
pub(crate) struct ShardPipeline {
    pub ingress: BTreeMap<String, SyncSender<QueuedRequest>>,
    threads: Vec<JoinHandle<()>>,
    /// Tells the autoscale controllers to stop before the joins.
    stop: Arc<AtomicBool>,
}

impl ShardPipeline {
    /// Graceful shutdown: close the ingress, let the close cascade drain
    /// every stage, then join.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.ingress.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Batches in flight between a batcher and its executor: enough to keep
/// the executor busy while the next batch forms, small enough that
/// backpressure reaches the ingress quickly.
const EXEC_QUEUE_BATCHES: usize = 2;
/// Finished batches queued for a shard's responder.
const FINISHED_QUEUE_BATCHES: usize = 8;
/// Idle executor workers re-check the scale target this often.
const WORKER_POLL: Duration = Duration::from_millis(25);

/// Everything an executor worker (or the controller that spawns more of
/// them) needs; cheap to clone, one per worker thread.
#[derive(Clone)]
struct ExecPool {
    shard: usize,
    variant: String,
    factory: Arc<dyn BackendFactory>,
    health: Arc<Health>,
    res: Arc<ResilienceRuntime>,
    rx: Arc<Mutex<Receiver<Vec<QueuedRequest>>>>,
    /// Desired worker count; workers with `id >= target` retire.
    target: Arc<AtomicUsize>,
    finished: FinishedTx,
}

pub(crate) fn spawn_shard(ctx: ShardCtx) -> Result<ShardPipeline> {
    let (fin_tx, fin_rx) = sync_channel::<Finished>(FINISHED_QUEUE_BATCHES);
    let mut ingress = BTreeMap::new();
    let mut threads = Vec::new();
    let stop = Arc::new(AtomicBool::new(false));
    for variant in &ctx.variants {
        let (in_tx, in_rx) = sync_channel::<QueuedRequest>(ctx.queue_limit.max(1));
        ingress.insert(variant.clone(), in_tx);
        let (ex_tx, ex_rx) = sync_channel::<Vec<QueuedRequest>>(EXEC_QUEUE_BATCHES);
        // Never form more than one backend execution's worth.
        let policy = BatchPolicy {
            max_batch: ctx.policy.max_batch.min(ctx.factory.max_batch()).max(1),
            ..ctx.policy
        };
        threads.push(spawn_batcher(
            ctx.shard,
            variant.clone(),
            in_rx,
            ex_tx,
            fin_tx.clone(),
            policy,
            Arc::clone(&ctx.res),
        )?);
        let pool = ExecPool {
            shard: ctx.shard,
            variant: variant.clone(),
            factory: Arc::clone(&ctx.factory),
            health: Arc::clone(&ctx.health),
            res: Arc::clone(&ctx.res),
            rx: Arc::new(Mutex::new(ex_rx)),
            target: Arc::new(AtomicUsize::new(1)),
            finished: fin_tx.clone(),
        };
        // Worker 0 is immortal (never retired by scale-down) and the one
        // that reports boot readiness.
        threads.push(spawn_exec_worker(pool.clone(), 0, Some(ctx.ready.clone()))?);
        if let Some(autoscale) = ctx.res.cfg.autoscale {
            threads.push(spawn_scaler(pool, autoscale, Arc::clone(&stop))?);
        }
    }
    // The responder must see disconnect once batchers + executors exit.
    drop(fin_tx);
    threads.push(spawn_responder(
        ctx.shard,
        fin_rx,
        Arc::clone(&ctx.metrics),
        Arc::clone(&ctx.res),
    )?);
    Ok(ShardPipeline {
        ingress,
        threads,
        stop,
    })
}

/// Stage 2: deadline-bucket batching. Pulls from the bounded ingress,
/// closes batches per [`next_batch`]'s SLO rules, fails what already
/// expired in queue, and hands live batches to the executor (blocking —
/// that is the backpressure). Queue-wait samples additionally feed the
/// resilience layer's pressure EMA (autoscaling + degradation ladder).
fn spawn_batcher(
    shard: usize,
    variant: String,
    rx: Receiver<QueuedRequest>,
    exec: SyncSender<Vec<QueuedRequest>>,
    finished: FinishedTx,
    policy: BatchPolicy,
    res: Arc<ResilienceRuntime>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("batch-{shard}-{variant}"))
        .spawn(move || {
            let queue_wait = crate::obs::histogram("serve.queue_wait_us");
            let slack = crate::obs::histogram("serve.deadline_slack_us");
            let expired = crate::obs::counter("serve.deadline_expired");
            while let Some(batch) = next_batch(&rx, &policy, |q: &QueuedRequest| q.deadline) {
                // Explicit full path: the executor (a different thread)
                // parents its span under this one via
                // `span_path("serve.batch/execute")`.
                let _batch_span = crate::obs::span_path("serve.batch");
                let t_batch = if crate::obs::trace_enabled() {
                    crate::obs::trace::now_us()
                } else {
                    0
                };
                let now = Instant::now();
                let mut live = Vec::with_capacity(batch.len());
                let mut dead = Vec::new();
                for mut q in batch {
                    let wait_us = q.enqueued.elapsed().as_micros() as u64;
                    queue_wait.record(wait_us);
                    res.note_queue_wait(shard, &variant, wait_us);
                    if q.deadline <= now {
                        dead.push(q);
                    } else {
                        slack.record(q.deadline.saturating_duration_since(now).as_micros() as u64);
                        q.stamps.stamp_batch(t_batch);
                        live.push(q);
                    }
                }
                if !dead.is_empty() {
                    expired.add(dead.len() as u64);
                    forward(
                        &finished,
                        shard as u32,
                        Finished::Failed {
                            variant: variant.clone(),
                            batch: dead,
                            reason: FailReason::DeadlineExpired,
                        },
                    );
                }
                if live.is_empty() {
                    continue;
                }
                if let Err(err) = exec.send(live) {
                    // Executor gone (failed boot / poisoned shutdown):
                    // the batch must still be delivered, as failures.
                    forward(
                        &finished,
                        shard as u32,
                        Finished::Failed {
                            variant: variant.clone(),
                            batch: err.0,
                            reason: FailReason::WorkerPanicked,
                        },
                    );
                }
            }
        })
        .context("spawning batcher thread")
}

/// Stage 3: execution. Each worker owns its backend (built on the worker
/// thread — PJRT executables are per-thread, the native backend keeps
/// per-worker scratch); every `infer_batch` runs under `catch_unwind`.
/// Transient failures retry with backoff; a panic respawns the backend
/// while the [`RestartBudget`] lasts, then falls back to the legacy
/// poison-and-report-[`Health`] behavior. Workers with `id > 0` retire
/// when the autoscale target drops below them.
fn spawn_exec_worker(
    pool: ExecPool,
    worker_id: usize,
    ready: Option<Sender<std::result::Result<(), String>>>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("exec-{}-{}-w{worker_id}", pool.shard, pool.variant))
        .spawn(move || {
            let mut backend: Box<dyn Backend> =
                match pool.factory.create_for_shard(pool.shard, &pool.variant) {
                    Ok(b) => {
                        if let Some(r) = &ready {
                            // Boot may already have failed on a sibling; a
                            // closed channel is fine to ignore.
                            let _ = r.send(Ok(()));
                        }
                        b
                    }
                    Err(e) => {
                        match &ready {
                            Some(r) => {
                                let _ = r.send(Err(format!("{}: {e:#}", pool.variant)));
                            }
                            None => {
                                // A scaled-up worker that cannot build its
                                // backend rolls the target back so the
                                // controller can try again later.
                                crate::obs::error(
                                    "serve",
                                    "autoscaled worker failed to build backend",
                                    &[
                                        ("variant", pool.variant.clone()),
                                        ("error", format!("{e:#}")),
                                    ],
                                );
                                let _ = pool.target.fetch_update(
                                    Ordering::Relaxed,
                                    Ordering::Relaxed,
                                    |t| if t > 1 { Some(t - 1) } else { None },
                                );
                            }
                        }
                        return;
                    }
                };
            drop(ready);
            let workers_gauge = crate::obs::gauge("serve.autoscale.workers");
            workers_gauge.add(1);
            let execute_failures = crate::obs::counter("serve.execute_failures");
            let retry_attempts = crate::obs::counter("serve.retry.attempts");
            let retry_recovered = crate::obs::counter("serve.retry.recovered");
            let respawns = crate::obs::counter("serve.executor.respawns");
            let cfg = pool.res.cfg;
            let mut budget = RestartBudget::new(cfg.respawn_budget, cfg.respawn_min_interval);
            let mut poisoned = false;
            loop {
                if worker_id != 0 && worker_id >= pool.target.load(Ordering::Relaxed) {
                    break; // retired by scale-down
                }
                let recv = {
                    let guard = match pool.rx.lock() {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    };
                    guard.recv_timeout(WORKER_POLL)
                };
                let mut batch = match recv {
                    Ok(b) => b,
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                };
                if poisoned {
                    forward(
                        &pool.finished,
                        pool.shard as u32,
                        Finished::Failed {
                            variant: pool.variant.clone(),
                            batch,
                            reason: FailReason::WorkerPanicked,
                        },
                    );
                    continue;
                }
                let mut attempts_left = cfg.retries;
                let mut retried = false;
                let msg = loop {
                    let traced = crate::obs::trace_enabled();
                    let t_exec_start = if traced { crate::obs::trace::now_us() } else { 0 };
                    let result = {
                        // Full-path span: this thread's TLS stack is empty,
                        // but the batch stage semantically parents execution.
                        let _execute = crate::obs::span_path("serve.batch/execute");
                        let images: Vec<&[u8]> =
                            batch.iter().map(|q| q.image.as_slice()).collect();
                        catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&images)))
                    };
                    if traced {
                        // Re-stamped on retry: the trace records the
                        // attempt that produced the final outcome.
                        let t_exec_end = crate::obs::trace::now_us();
                        for q in &mut batch {
                            q.stamps.stamp_exec(t_exec_start, t_exec_end);
                        }
                    }
                    match result {
                        Ok(Ok(rows)) if rows.len() == batch.len() => {
                            if retried {
                                retry_recovered.add(batch.len() as u64);
                            }
                            break Finished::Executed {
                                variant: pool.variant.clone(),
                                batch,
                                rows,
                            };
                        }
                        Ok(Ok(rows)) => {
                            if attempts_left > 0 {
                                attempts_left -= 1;
                                retried = true;
                                retry_attempts.inc();
                                std::thread::sleep(backoff(cfg.retry_backoff, cfg.retries, attempts_left));
                                continue;
                            }
                            crate::obs::error(
                                "serve",
                                "backend returned a short batch",
                                &[
                                    ("variant", pool.variant.clone()),
                                    ("rows", rows.len().to_string()),
                                    ("batch", batch.len().to_string()),
                                ],
                            );
                            execute_failures.inc();
                            break Finished::Failed {
                                variant: pool.variant.clone(),
                                reason: FailReason::ExecuteFailed(format!(
                                    "backend returned {} rows for a batch of {}",
                                    rows.len(),
                                    batch.len()
                                )),
                                batch,
                            };
                        }
                        Ok(Err(e)) => {
                            if attempts_left > 0 {
                                attempts_left -= 1;
                                retried = true;
                                retry_attempts.inc();
                                std::thread::sleep(backoff(cfg.retry_backoff, cfg.retries, attempts_left));
                                continue;
                            }
                            crate::obs::error(
                                "serve",
                                "execute failed",
                                &[
                                    ("variant", pool.variant.clone()),
                                    ("error", format!("{e:#}")),
                                ],
                            );
                            execute_failures.inc();
                            break Finished::Failed {
                                variant: pool.variant.clone(),
                                batch,
                                reason: FailReason::ExecuteFailed(format!("{e:#}")),
                            };
                        }
                        Err(panic) => {
                            let what = panic_message(panic.as_ref());
                            crate::obs::error(
                                "serve",
                                "worker panicked during execute",
                                &[
                                    ("shard", pool.shard.to_string()),
                                    ("variant", pool.variant.clone()),
                                    ("panic", what.clone()),
                                ],
                            );
                            execute_failures.inc();
                            match budget.request(Instant::now()) {
                                Some(wait) => {
                                    // Self-healing: rebuild the backend on
                                    // this thread (rate-limited) and keep
                                    // serving instead of poisoning.
                                    if !wait.is_zero() {
                                        std::thread::sleep(wait);
                                    }
                                    match pool.factory.create_for_shard(pool.shard, &pool.variant)
                                    {
                                        Ok(b) => {
                                            backend = b;
                                            respawns.inc();
                                            crate::obs::warn(
                                                "serve",
                                                "executor respawned after panic",
                                                &[
                                                    ("shard", pool.shard.to_string()),
                                                    ("variant", pool.variant.clone()),
                                                    ("respawn", budget.used().to_string()),
                                                ],
                                            );
                                            if attempts_left > 0 {
                                                attempts_left -= 1;
                                                retried = true;
                                                retry_attempts.inc();
                                                continue;
                                            }
                                            break Finished::Failed {
                                                variant: pool.variant.clone(),
                                                batch,
                                                reason: FailReason::WorkerPanicked,
                                            };
                                        }
                                        Err(e) => {
                                            pool.health.report(format!(
                                                "shard {} variant {} respawn failed after \
                                                 panic: {e:#}",
                                                pool.shard, pool.variant
                                            ));
                                            poisoned = true;
                                            break Finished::Failed {
                                                variant: pool.variant.clone(),
                                                batch,
                                                reason: FailReason::WorkerPanicked,
                                            };
                                        }
                                    }
                                }
                                None => {
                                    if cfg.respawn_budget == 0 {
                                        pool.health.report(format!(
                                            "shard {} variant {} worker panicked: {what}",
                                            pool.shard, pool.variant
                                        ));
                                    } else {
                                        pool.health.report(format!(
                                            "shard {} variant {} worker panicked: {what} \
                                             (restart budget exhausted after {} respawns)",
                                            pool.shard,
                                            pool.variant,
                                            budget.used()
                                        ));
                                    }
                                    poisoned = true;
                                    break Finished::Failed {
                                        variant: pool.variant.clone(),
                                        batch,
                                        reason: FailReason::WorkerPanicked,
                                    };
                                }
                            }
                        }
                    }
                };
                forward(&pool.finished, pool.shard as u32, msg);
            }
            workers_gauge.add(-1);
        })
        .context("spawning executor worker thread")
}

/// Linear backoff: the Nth retry of a batch sleeps `N * base`.
fn backoff(base: Duration, retries: u32, attempts_left: u32) -> Duration {
    base * (retries - attempts_left).max(1)
}

/// The autoscale controller for one shard×variant pool: each tick it
/// reads (then decays) the queue-wait pressure EMA and grows or shrinks
/// the worker target within `1..=max_workers`. Spawned workers are
/// owned (and joined) here; retiring workers notice the lowered target
/// within [`WORKER_POLL`].
fn spawn_scaler(
    pool: ExecPool,
    policy: AutoscalePolicy,
    stop: Arc<AtomicBool>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("scale-{}-{}", pool.shard, pool.variant))
        .spawn(move || {
            let ups = crate::obs::counter("serve.autoscale.scale_ups");
            let downs = crate::obs::counter("serve.autoscale.scale_downs");
            let mut spawned: BTreeMap<usize, JoinHandle<()>> = BTreeMap::new();
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(policy.tick);
                let wait = Duration::from_micros(pool.res.queue_wait_us(pool.shard, &pool.variant));
                pool.res.decay_pressure(pool.shard, &pool.variant);
                let current = pool.target.load(Ordering::Relaxed);
                match autoscale_decision(&policy, current, wait) {
                    Some(next) if next > current => {
                        // Reap any previous incarnation of the ids being
                        // brought back so two threads never share one.
                        for id in current..next {
                            if let Some(h) = spawned.remove(&id) {
                                let _ = h.join();
                            }
                        }
                        pool.target.store(next, Ordering::Relaxed);
                        for id in current..next {
                            match spawn_exec_worker(pool.clone(), id, None) {
                                Ok(h) => {
                                    spawned.insert(id, h);
                                    ups.inc();
                                    crate::obs::info(
                                        "serve",
                                        "autoscale: worker added",
                                        &[
                                            ("shard", pool.shard.to_string()),
                                            ("variant", pool.variant.clone()),
                                            ("workers", next.to_string()),
                                            ("queue_wait_us", wait.as_micros().to_string()),
                                        ],
                                    );
                                }
                                Err(_) => {
                                    pool.target.store(current, Ordering::Relaxed);
                                    break;
                                }
                            }
                        }
                    }
                    Some(next) if next < current => {
                        pool.target.store(next, Ordering::Relaxed);
                        downs.inc();
                        crate::obs::info(
                            "serve",
                            "autoscale: worker retiring",
                            &[
                                ("shard", pool.shard.to_string()),
                                ("variant", pool.variant.clone()),
                                ("workers", next.to_string()),
                            ],
                        );
                    }
                    _ => {}
                }
            }
            for (_, h) in spawned {
                let _ = h.join();
            }
        })
        .context("spawning autoscale controller thread")
}

/// Stage 4: the shard's single responder — metrics, delivery counters and
/// the per-request `Delivery` sends, off the executor's critical path.
/// Execution outcomes feed the circuit breakers here (deadline expiries
/// do not — they indict the queue, not the backend), and hedged
/// duplicates are claimed out before anything is counted.
fn spawn_responder(
    shard: usize,
    rx: Receiver<Finished>,
    metrics: Arc<ServerMetrics>,
    res: Arc<ResilienceRuntime>,
) -> Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("respond-{shard}"))
        .spawn(move || {
            let shard_delivered = crate::obs::counter(&format!("serve.shard{shard}.delivered"));
            let shard_failed = crate::obs::counter(&format!("serve.shard{shard}.failed"));
            let delivered = crate::obs::counter("serve.responses_delivered");
            let delivered_late = crate::obs::counter("serve.delivered_late");
            let fail_expired = crate::obs::counter("serve.failed.deadline_expired");
            let fail_execute = crate::obs::counter("serve.failed.execute");
            let fail_panic = crate::obs::counter("serve.failed.worker_panic");
            while let Ok(msg) = rx.recv() {
                let _respond = crate::obs::span("respond");
                match msg {
                    Finished::Executed {
                        variant,
                        batch,
                        rows,
                    } => {
                        if res.breakers_on() {
                            let epochs: Vec<u64> =
                                batch.iter().map(|q| q.breaker_epoch).collect();
                            res.on_batch_outcome(&variant, true, &epochs);
                        }
                        // Claim out hedged duplicates first: only winning
                        // copies are counted and delivered.
                        let t_done = trace_now();
                        let mut winners: Vec<(QueuedRequest, Vec<f32>)> =
                            Vec::with_capacity(batch.len());
                        let mut dups = 0usize;
                        for (q, logits) in batch.into_iter().zip(rows) {
                            if q.respond.claim_ok() {
                                winners.push((q, logits));
                            } else {
                                dups += 1;
                                complete_trace(&q.stamps, shard as u32, &variant, t_done);
                            }
                        }
                        if dups > 0 {
                            metrics.record_hedge_discarded(dups);
                        }
                        let degraded = winners.iter().filter(|(q, _)| q.degraded).count();
                        if degraded > 0 {
                            metrics.record_degraded(degraded);
                        }
                        // Record metrics BEFORE completing the requests so
                        // a caller that snapshots right after the last
                        // response sees every batch counted. Latencies
                        // carry the trace id as a histogram exemplar —
                        // `obs health` links p99 to a concrete request.
                        let lats: Vec<(f64, u64)> = winners
                            .iter()
                            .map(|(q, _)| (q.enqueued.elapsed().as_micros() as f64, q.stamps.id))
                            .collect();
                        metrics.record_batch_exemplars(winners.len(), &lats);
                        delivered.add(winners.len() as u64);
                        shard_delivered.add(winners.len() as u64);
                        // Deliveries that landed past their deadline feed
                        // the latency SLO objective.
                        let now = Instant::now();
                        let late = winners.iter().filter(|(q, _)| now > q.deadline).count();
                        if late > 0 {
                            delivered_late.add(late as u64);
                        }
                        deliver_claimed(shard as u32, variant, winners);
                    }
                    Finished::Failed {
                        variant,
                        batch,
                        reason,
                    } => {
                        // Deadline expiries never reach the breaker: they
                        // indict queueing pressure, not the backend — but
                        // an expired half-open probe must hand its slot
                        // back or the round would leak it.
                        if res.breakers_on() {
                            let epochs: Vec<u64> =
                                batch.iter().map(|q| q.breaker_epoch).collect();
                            if matches!(reason, FailReason::DeadlineExpired) {
                                res.probe_abort_batch(&variant, &epochs);
                            } else {
                                res.on_batch_outcome(&variant, false, &epochs);
                            }
                        }
                        let (deliverable, discarded) =
                            settle_failures(shard as u32, &variant, batch, &reason);
                        if discarded > 0 {
                            metrics.record_hedge_discarded(discarded);
                        }
                        let n = deliverable.len() as u64;
                        metrics.record_failed(deliverable.len());
                        shard_failed.add(n);
                        match &reason {
                            FailReason::DeadlineExpired => fail_expired.add(n),
                            FailReason::ExecuteFailed(_) => fail_execute.add(n),
                            FailReason::WorkerPanicked => fail_panic.add(n),
                        }
                        send_failures(shard as u32, &variant, deliverable, reason);
                    }
                }
            }
        })
        .context("spawning responder thread")
}

/// Hand a finished batch to the responder; if the responder is already
/// gone (shutdown tail, boot teardown), deliver directly — an admitted
/// request gets exactly one delivery (and one trace completion) on every
/// path.
fn forward(finished: &FinishedTx, shard: u32, msg: Finished) {
    if let Err(err) = finished.send(msg) {
        match err.0 {
            Finished::Executed {
                variant,
                batch,
                rows,
            } => deliver_rows(shard, variant, batch, rows),
            Finished::Failed {
                variant,
                batch,
                reason,
            } => fail_batch(shard, &variant, batch, reason),
        }
    }
}

/// Current µs timestamp for trace completion, free when tracing is off.
fn trace_now() -> u64 {
    if crate::obs::trace_enabled() {
        crate::obs::trace::now_us()
    } else {
        0
    }
}

/// Close a (possibly untraced) request timeline as delivered.
fn complete_trace(stamps: &StageStamps, shard: u32, variant: &str, t_done: u64) {
    if stamps.id != 0 {
        crate::obs::trace::collector().complete((*stamps).finish(
            shard,
            variant,
            TraceOutcome::Delivered,
            t_done,
        ));
    }
}

/// Metrics-free delivery used by the [`forward`] fallback: claim out
/// duplicates, then deliver the winners.
fn deliver_rows(shard: u32, variant: String, batch: Vec<QueuedRequest>, rows: Vec<Vec<f32>>) {
    let t_done = trace_now();
    let mut winners = Vec::with_capacity(batch.len());
    for (q, logits) in batch.into_iter().zip(rows) {
        if q.respond.claim_ok() {
            winners.push((q, logits));
        } else {
            complete_trace(&q.stamps, shard, &variant, t_done);
        }
    }
    deliver_claimed(shard, variant, winners);
}

/// Deliver rows whose slots have already been claimed.
fn deliver_claimed(shard: u32, variant: String, winners: Vec<(QueuedRequest, Vec<f32>)>) {
    let t_done = trace_now();
    for (q, logits) in winners {
        complete_trace(&q.stamps, shard, &variant, t_done);
        let predicted = argmax(&logits);
        q.respond.send(Delivery::Ok(Response {
            logits,
            predicted,
            variant: variant.clone(),
            degraded: q.degraded,
        }));
    }
}

/// Partition a failed batch by hedge disposition: requests this copy must
/// deliver a failure for come back; pending copies (a sibling will
/// settle) and discarded copies (a sibling already delivered) have their
/// traces closed here and are dropped. Returns the deliverable requests
/// plus the discarded-duplicate count.
fn settle_failures(
    shard: u32,
    variant: &str,
    batch: Vec<QueuedRequest>,
    reason: &FailReason,
) -> (Vec<QueuedRequest>, usize) {
    let outcome = match reason {
        FailReason::DeadlineExpired => TraceOutcome::DeadlineExpired,
        FailReason::ExecuteFailed(_) => TraceOutcome::ExecuteFailed,
        FailReason::WorkerPanicked => TraceOutcome::WorkerPanicked,
    };
    let t_done = trace_now();
    let mut deliverable = Vec::with_capacity(batch.len());
    let mut discarded = 0usize;
    for q in batch {
        match q.respond.fail_disposition() {
            FailDisposition::Deliver => deliverable.push(q),
            FailDisposition::Pending => {
                // The sibling copy settles the client delivery; this
                // copy still owns the trace, closed with its own fate.
                if q.stamps.id != 0 {
                    crate::obs::trace::collector().complete(q.stamps.finish(
                        shard, variant, outcome, t_done,
                    ));
                }
            }
            FailDisposition::Discard => {
                discarded += 1;
                complete_trace(&q.stamps, shard, variant, t_done);
            }
        }
    }
    (deliverable, discarded)
}

/// Send a failure to every deliverable request, closing each trace with
/// the outcome matching the [`FailReason`].
fn send_failures(shard: u32, variant: &str, batch: Vec<QueuedRequest>, reason: FailReason) {
    let outcome = match &reason {
        FailReason::DeadlineExpired => TraceOutcome::DeadlineExpired,
        FailReason::ExecuteFailed(_) => TraceOutcome::ExecuteFailed,
        FailReason::WorkerPanicked => TraceOutcome::WorkerPanicked,
    };
    let t_done = trace_now();
    for q in batch {
        if q.stamps.id != 0 {
            crate::obs::trace::collector().complete(q.stamps.finish(
                shard, variant, outcome, t_done,
            ));
        }
        q.respond.send(Delivery::Failed(reason.clone()));
    }
}

/// Metrics-free failure delivery used by the [`forward`] fallback.
fn fail_batch(shard: u32, variant: &str, batch: Vec<QueuedRequest>, reason: FailReason) {
    let (deliverable, _discarded) = settle_failures(shard, variant, batch, &reason);
    send_failures(shard, variant, deliverable, reason);
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn direct_slot_always_claims_and_delivers_failures() {
        let (tx, _rx) = channel();
        let slot = ResponseSlot::direct(tx);
        assert!(slot.claim_ok());
        assert!(slot.claim_ok());
        assert!(matches!(slot.fail_disposition(), FailDisposition::Deliver));
    }

    #[test]
    fn hedged_pair_claims_success_exactly_once() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(primary.claim_ok());
        assert!(!hedge.claim_ok());
    }

    #[test]
    fn hedged_failure_then_success_delivers_once() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        // Primary fails first: the hedge is still outstanding, so the
        // failure stays pending.
        assert!(matches!(
            primary.fail_disposition(),
            FailDisposition::Pending
        ));
        // Hedge succeeds and claims the one delivery.
        assert!(hedge.claim_ok());
    }

    #[test]
    fn hedged_double_failure_delivers_the_last_one() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(matches!(
            primary.fail_disposition(),
            FailDisposition::Pending
        ));
        assert!(matches!(hedge.fail_disposition(), FailDisposition::Deliver));
    }

    #[test]
    fn failure_after_sibling_success_is_discarded() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(primary.claim_ok());
        assert!(matches!(hedge.fail_disposition(), FailDisposition::Discard));
    }

    #[test]
    fn cancelled_hedge_makes_primary_failure_deliverable() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(matches!(hedge.cancel(), FailDisposition::Pending));
        assert!(matches!(
            primary.fail_disposition(),
            FailDisposition::Deliver
        ));
    }

    #[test]
    fn cancel_after_primary_failure_must_deliver() {
        // The lost-delivery race: the primary fails (and defers,
        // seeing the hedge outstanding) before the bounced hedge
        // cancels — the cancel is the last settler and must deliver.
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(matches!(
            primary.fail_disposition(),
            FailDisposition::Pending
        ));
        assert!(matches!(hedge.cancel(), FailDisposition::Deliver));
    }

    #[test]
    fn cancel_after_primary_success_is_discarded() {
        let (tx, _rx) = channel();
        let (primary, hedge) = ResponseSlot::hedged_pair(tx);
        assert!(primary.claim_ok());
        assert!(matches!(hedge.cancel(), FailDisposition::Discard));
    }
}
