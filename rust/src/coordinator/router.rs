//! Request routing for the sharded serving layer: accuracy-class →
//! cheapest-satisfying-variant selection, and a consistent-hash ring
//! spreading requests across coordinator shards.
//!
//! ## Accuracy-class routing rules
//!
//! A request may name its serving variant explicitly (the historical wire
//! format) or carry an [`AccuracyClass`] — a maximum acceptable top-1 drop
//! vs the all-exact baseline. The [`RoutingTable`] holds one entry per
//! servable variant whose calibration accuracy the design-point store (or
//! a compiled plan artifact) has measured, ordered cheapest-first by
//! energy per multiply. Selection is:
//!
//! 1. the **cheapest** entry with `drop_vs_exact <= class.max_drop` wins;
//! 2. if no measured entry satisfies the class, the router **falls back to
//!    exact** (drop 0 by definition) and flags the decision, so the
//!    `serve.route.fallback_exact` counter exposes classes the current
//!    variant menu cannot serve cheaply;
//! 3. ties break by variant name, so decisions are deterministic for any
//!    table construction order.
//!
//! The accuracy column comes from the same `"compile-accuracy/1"` store
//! records the compile pass persists (uniform per-family assignments), or
//! from the `.acmplan` artifact for compiled-plan variants — see
//! [`super::warmstart`]. The energy column is the PPA engine's J/op.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

use super::warmstart::{profile_for_variant, VariantProfile};
use crate::store::key::checksum64;

/// The accuracy constraint a request carries: the largest top-1 drop vs
/// the all-exact baseline the caller will accept, as a fraction.
#[derive(Clone, Debug, PartialEq)]
pub struct AccuracyClass {
    /// Class label (metrics, logs); named tiers keep their tier name.
    pub name: String,
    /// Maximum acceptable top-1 drop vs exact, in [0, 1].
    pub max_drop: f64,
}

impl AccuracyClass {
    pub fn new(name: impl Into<String>, max_drop: f64) -> AccuracyClass {
        AccuracyClass {
            name: name.into(),
            max_drop,
        }
    }

    /// Parse a class from the wire/CLI form: a named tier (`exact`,
    /// `gold`, `silver`, `bronze`, `best-effort`) or an explicit drop
    /// budget — a fraction (`0.01`) or a percentage (`1%`).
    pub fn parse(s: &str) -> Result<AccuracyClass> {
        let tier = |name: &str, d: f64| Ok(AccuracyClass::new(name, d));
        match s {
            "exact" => return tier("exact", 0.0),
            "gold" => return tier("gold", 0.001),
            "silver" => return tier("silver", 0.005),
            "bronze" => return tier("bronze", 0.02),
            "best-effort" => return tier("best-effort", 1.0),
            _ => {}
        }
        let (num, scale) = match s.strip_suffix('%') {
            Some(pct) => (pct, 0.01),
            None => (s, 1.0),
        };
        let drop: f64 = match num.parse::<f64>() {
            Ok(v) => v * scale,
            Err(_) => bail!(
                "unknown accuracy class {s:?} (expected exact|gold|silver|bronze|best-effort, \
                 a fraction like 0.01, or a percentage like 1%)"
            ),
        };
        if !(0.0..=1.0).contains(&drop) {
            bail!("accuracy-class drop budget {drop} outside [0, 1]");
        }
        Ok(AccuracyClass::new(format!("drop<={s}"), drop))
    }
}

/// One variant the class router may select.
#[derive(Clone, Debug)]
pub struct RouteEntry {
    /// Serving variant (route key), e.g. `logour` or `plan`.
    pub variant: String,
    /// Measured calibration top-1 drop vs the all-exact baseline.
    pub drop_vs_exact: f64,
    /// Energy per multiply, J — the cost being minimized. Variants with
    /// no PPA characterization rank last (`f64::INFINITY`).
    pub energy_per_op_j: f64,
}

/// The outcome of routing one accuracy class.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteDecision {
    pub variant: String,
    /// No measured variant satisfied the class; `variant` is the exact
    /// fallback.
    pub fallback: bool,
    /// A cheaper satisfying variant existed but was unavailable (open
    /// circuit breaker, queue pressure) — the request was degraded to
    /// the next rung of the ladder.
    pub degraded: bool,
}

/// Cheapest-first table of accuracy-characterized serving variants.
#[derive(Clone, Debug, Default)]
pub struct RoutingTable {
    entries: Vec<RouteEntry>,
    exact: Option<String>,
}

impl RoutingTable {
    /// Build from explicit entries (tests and custom deployments).
    /// `exact` is the fallback variant; entries are re-sorted
    /// cheapest-first with deterministic name tie-breaks.
    pub fn new(mut entries: Vec<RouteEntry>, exact: Option<String>) -> RoutingTable {
        entries.sort_by(|a, b| {
            a.energy_per_op_j
                .total_cmp(&b.energy_per_op_j)
                .then_with(|| a.variant.cmp(&b.variant))
        });
        RoutingTable { entries, exact }
    }

    /// Assemble the table for the servable `variants` from warm-started
    /// profiles: a variant participates when its profile carries a
    /// measured calibration drop ([`VariantProfile::calib_drop`]); the
    /// variant literally named `exact` is the fallback and always
    /// participates with drop 0.
    pub fn from_profiles(
        profiles: &BTreeMap<String, VariantProfile>,
        variants: &[String],
    ) -> RoutingTable {
        let mut entries = Vec::new();
        let mut exact = None;
        for v in variants {
            let profile = profile_for_variant(profiles, v);
            let energy = profile
                .and_then(|p| p.energy_per_op_j)
                .unwrap_or(f64::INFINITY);
            let drop = if v == "exact" {
                exact = Some(v.clone());
                Some(0.0)
            } else {
                profile.and_then(|p| p.calib_drop)
            };
            if let Some(drop) = drop {
                entries.push(RouteEntry {
                    variant: v.clone(),
                    drop_vs_exact: drop,
                    energy_per_op_j: energy,
                });
            }
        }
        RoutingTable::new(entries, exact)
    }

    /// Route one class: cheapest satisfying entry, else the exact
    /// fallback, else `None` (nothing servable for this class).
    pub fn select(&self, class: &AccuracyClass) -> Option<RouteDecision> {
        self.select_with(class, |_| true)
    }

    /// Route one class through an availability predicate — the
    /// degradation ladder. Satisfying-but-unavailable variants are
    /// skipped (marking the decision [`RouteDecision::degraded`]); the
    /// exact fallback is subject to the same predicate. `None` means
    /// nothing both satisfies the class and is available right now —
    /// the caller decides between shed (candidates exist, all
    /// unavailable) and unroutable (no candidates at all).
    pub fn select_with(
        &self,
        class: &AccuracyClass,
        available: impl Fn(&str) -> bool,
    ) -> Option<RouteDecision> {
        let mut skipped = false;
        for e in &self.entries {
            if e.drop_vs_exact <= class.max_drop {
                if available(&e.variant) {
                    return Some(RouteDecision {
                        variant: e.variant.clone(),
                        fallback: false,
                        degraded: skipped,
                    });
                }
                skipped = true;
            }
        }
        self.exact
            .as_ref()
            .filter(|v| available(v))
            .map(|v| RouteDecision {
                variant: v.clone(),
                fallback: true,
                degraded: skipped,
            })
    }

    /// Entries, cheapest first (reporting and table-driven tests).
    pub fn entries(&self) -> &[RouteEntry] {
        &self.entries
    }

    /// The exact fallback variant, when one is being served.
    pub fn exact_fallback(&self) -> Option<&str> {
        self.exact.as_deref()
    }
}

// ---------------------------------------------------------------------------
// Consistent-hash shard ring
// ---------------------------------------------------------------------------

/// Virtual nodes per shard: enough that a 64-point-per-shard ring spreads
/// keys within a few percent of uniform.
pub const VNODES_PER_SHARD: usize = 64;

/// Consistent-hash ring over coordinator shards. Each shard owns
/// [`VNODES_PER_SHARD`] points; a request key maps to the first point at
/// or after it (wrapping). Deterministic: the same key always lands on
/// the same shard for a given shard count, and growing the ring moves
/// only the keys the new shard's points capture.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// (point hash, shard index), sorted by hash.
    points: Vec<(u64, u32)>,
    shards: usize,
}

impl HashRing {
    pub fn new(shards: usize) -> HashRing {
        let shards = shards.max(1);
        let mut points = Vec::with_capacity(shards * VNODES_PER_SHARD);
        for s in 0..shards {
            for v in 0..VNODES_PER_SHARD {
                let h = checksum64(format!("shard-{s}/vnode-{v}").as_bytes());
                points.push((h, s as u32));
            }
        }
        points.sort_unstable();
        HashRing { points, shards }
    }

    /// The routing key of a request payload.
    pub fn key_for(image: &[u8]) -> u64 {
        checksum64(image)
    }

    /// Map a key onto a shard index.
    pub fn shard_for(&self, key: u64) -> usize {
        let i = self.points.partition_point(|&(h, _)| h < key);
        let (_, shard) = self.points[if i == self.points.len() { 0 } else { i }];
        shard as usize
    }

    pub fn shards(&self) -> usize {
        self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> RoutingTable {
        // exact: drop 0, most expensive; three approximations with
        // increasing drops and decreasing energy.
        RoutingTable::new(
            vec![
                RouteEntry {
                    variant: "exact".into(),
                    drop_vs_exact: 0.0,
                    energy_per_op_j: 2.5e-12,
                },
                RouteEntry {
                    variant: "appro42".into(),
                    drop_vs_exact: 0.004,
                    energy_per_op_j: 2.1e-12,
                },
                RouteEntry {
                    variant: "lm".into(),
                    drop_vs_exact: 0.05,
                    energy_per_op_j: 1.2e-12,
                },
                RouteEntry {
                    variant: "logour".into(),
                    drop_vs_exact: 0.018,
                    energy_per_op_j: 1.4e-12,
                },
            ],
            Some("exact".into()),
        )
    }

    #[test]
    fn class_parse_tiers_and_numbers() {
        assert_eq!(AccuracyClass::parse("exact").unwrap().max_drop, 0.0);
        assert_eq!(AccuracyClass::parse("silver").unwrap().max_drop, 0.005);
        assert_eq!(AccuracyClass::parse("0.01").unwrap().max_drop, 0.01);
        assert!((AccuracyClass::parse("2%").unwrap().max_drop - 0.02).abs() < 1e-12);
        assert!(AccuracyClass::parse("platinum").is_err());
        assert!(AccuracyClass::parse("1.5").is_err());
        assert!(AccuracyClass::parse("-0.1").is_err());
    }

    #[test]
    fn select_picks_cheapest_satisfying_variant() {
        let t = table();
        // best-effort: everything satisfies; lm is cheapest.
        let d = t.select(&AccuracyClass::new("any", 1.0)).unwrap();
        assert_eq!(d.variant, "lm");
        assert!(!d.fallback);
        // 2% budget: lm (5%) is out; logour (1.8%) is the cheapest in.
        let d = t.select(&AccuracyClass::new("b", 0.02)).unwrap();
        assert_eq!(d.variant, "logour");
        // 0.5% budget: only appro42 (0.4%) and exact satisfy; appro42 is
        // cheaper.
        let d = t.select(&AccuracyClass::new("s", 0.005)).unwrap();
        assert_eq!(d.variant, "appro42");
        // 0.1% budget: nothing approximate satisfies — exact, not as a
        // fallback (it is a measured drop-0 entry).
        let d = t.select(&AccuracyClass::new("g", 0.001)).unwrap();
        assert_eq!(d.variant, "exact");
        assert!(!d.fallback);
    }

    #[test]
    fn select_falls_back_to_exact_when_no_entry_satisfies() {
        // A table with only uncharacterizable-beyond-budget entries.
        let t = RoutingTable::new(
            vec![RouteEntry {
                variant: "lm".into(),
                drop_vs_exact: 0.05,
                energy_per_op_j: 1.2e-12,
            }],
            Some("exact".into()),
        );
        let d = t.select(&AccuracyClass::new("tight", 0.001)).unwrap();
        assert_eq!(d.variant, "exact");
        assert!(d.fallback, "must be flagged as an exact fallback");
        // No exact served at all: the class is unroutable.
        let t = RoutingTable::new(vec![], None);
        assert!(t.select(&AccuracyClass::new("tight", 0.001)).is_none());
    }

    #[test]
    fn select_with_skips_unavailable_and_flags_degraded() {
        let t = table();
        let cls = AccuracyClass::new("b", 0.02);
        // Baseline: logour is the cheapest satisfying variant.
        let d = t.select(&cls).unwrap();
        assert_eq!(d.variant, "logour");
        assert!(!d.degraded);
        // logour unavailable: degrade to the next-cheapest satisfying
        // variant (appro42), flagged.
        let d = t.select_with(&cls, |v| v != "logour").unwrap();
        assert_eq!(d.variant, "appro42");
        assert!(d.degraded);
        assert!(!d.fallback);
        // Everything approximate unavailable: degrade all the way to the
        // measured exact entry.
        let d = t.select_with(&cls, |v| v == "exact").unwrap();
        assert_eq!(d.variant, "exact");
        assert!(d.degraded);
        // Nothing available at all: None — caller sheds.
        assert!(t.select_with(&cls, |_| false).is_none());
    }

    #[test]
    fn select_with_availability_gates_the_exact_fallback_too() {
        let t = RoutingTable::new(
            vec![RouteEntry {
                variant: "lm".into(),
                drop_vs_exact: 0.05,
                energy_per_op_j: 1.2e-12,
            }],
            Some("exact".into()),
        );
        let cls = AccuracyClass::new("tight", 0.001);
        // Fallback reachable: flagged fallback, not degraded (nothing
        // satisfying was skipped — lm never qualified).
        let d = t.select_with(&cls, |_| true).unwrap();
        assert_eq!(d.variant, "exact");
        assert!(d.fallback);
        assert!(!d.degraded);
        // Fallback's breaker open: None.
        assert!(t.select_with(&cls, |v| v != "exact").is_none());
    }

    #[test]
    fn equal_cost_ties_break_by_name_deterministically() {
        let mk = |order: &[&str]| {
            let entries = order
                .iter()
                .map(|v| RouteEntry {
                    variant: v.to_string(),
                    drop_vs_exact: 0.0,
                    energy_per_op_j: 1e-12,
                })
                .collect();
            RoutingTable::new(entries, None)
        };
        let a = mk(&["b", "a", "c"]);
        let b = mk(&["c", "b", "a"]);
        let cls = AccuracyClass::new("any", 1.0);
        assert_eq!(a.select(&cls), b.select(&cls));
        assert_eq!(a.select(&cls).unwrap().variant, "a");
    }

    #[test]
    fn ring_is_deterministic_and_roughly_balanced() {
        let ring = HashRing::new(4);
        let again = HashRing::new(4);
        let mut counts = [0usize; 4];
        for i in 0..40_000u64 {
            let key = checksum64(&i.to_le_bytes());
            let s = ring.shard_for(key);
            assert_eq!(s, again.shard_for(key), "ring must be stable");
            counts[s] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (4_000..=21_000).contains(&c),
                "shard {s} got {c}/40000 keys — ring badly unbalanced: {counts:?}"
            );
        }
        // Single-shard ring routes everything to shard 0.
        let one = HashRing::new(1);
        assert_eq!(one.shard_for(u64::MAX), 0);
        assert_eq!(one.shard_for(0), 0);
    }

    #[test]
    fn growing_the_ring_only_moves_keys_to_the_new_shard() {
        let r4 = HashRing::new(4);
        let r5 = HashRing::new(5);
        let mut moved_elsewhere = 0;
        for i in 0..20_000u64 {
            let key = checksum64(&i.to_le_bytes());
            let (a, b) = (r4.shard_for(key), r5.shard_for(key));
            if a != b && b != 4 {
                moved_elsewhere += 1;
            }
        }
        assert_eq!(
            moved_elsewhere, 0,
            "consistent hashing: keys may only move to the added shard"
        );
    }
}
