//! Inference coordinator (Layer 3 serving path): a threaded request
//! router + dynamic batcher executing through a pluggable
//! [`crate::runtime::Backend`] — the AOT-compiled quantized-CNN graph via
//! PJRT, or the batched Rust-native quantized CNN with zero artifacts.
//! Python is never on this path.
//!
//! Design (vllm-router-like, scaled to this workload):
//!
//! * clients submit single-image classification requests tagged with a
//!   multiplier *variant* (exact / appro42 / logour / lm);
//! * the router keeps one dynamic batcher per variant; a batcher drains its
//!   queue until `batch` requests or `max_wait` elapses and hands the whole
//!   batch to its backend (`infer_batch`), then completes each request with
//!   its logits;
//! * each batcher worker owns its backend instance, built on the worker
//!   thread by a [`crate::runtime::BackendFactory`] (PJRT executables are
//!   per-thread; on the PJRT path all variants share one *graph* — the LUT
//!   is a runtime operand, so switching precision never recompiles);
//! * metrics: per-request latency (enqueue→response) percentiles and
//!   aggregate throughput, plus the per-inference energy estimate from the
//!   PPA engine (the paper's accuracy-energy headline, measured end to
//!   end in examples/e2e_serving.rs).

pub mod admission;
pub mod batcher;
pub mod server;
pub mod metrics;
pub mod warmstart;
pub mod cli;

pub use admission::{Admission, AdmissionController};
pub use metrics::ServerMetrics;
pub use server::{InferenceServer, Request, Response};
pub use warmstart::{plan_profile, profile_for_variant, warm_start_profiles, VariantProfile};
