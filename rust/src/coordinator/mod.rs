//! Inference coordinator (Layer 3 serving path): a **sharded**, SLO-aware
//! request router + deadline-bucket dynamic batcher executing through a
//! pluggable [`crate::runtime::Backend`] — the AOT-compiled quantized-CNN
//! graph via PJRT, or the batched Rust-native quantized CNN with zero
//! artifacts. Python is never on this path.
//!
//! Design (vllm-router-like, scaled to this workload; full stage diagram
//! in DESIGN.md §"Sharded serving"):
//!
//! * clients submit single-image classification requests routed either by
//!   multiplier *variant* (exact / appro42 / logour / lm / plan) or by an
//!   [`router::AccuracyClass`] — the router picks the cheapest variant
//!   whose store-measured calibration accuracy satisfies the class,
//!   falling back to exact ([`router::RoutingTable`]);
//! * requests spread across N coordinator shards by consistent hashing of
//!   the payload ([`router::HashRing`]); within a shard each variant runs
//!   admission → batching → execute → respond as decoupled stages over
//!   **bounded** channels ([`pipeline`]) — overload becomes backpressure
//!   and typed sheds, never unbounded queues;
//! * the batcher closes batches on size, window, **or SLO-deadline
//!   proximity** ([`batcher::next_batch`]); requests whose deadline
//!   expired in queue fail fast with
//!   [`server::FailReason::DeadlineExpired`];
//! * each executor owns its backend instance, built on the executor
//!   thread by a [`crate::runtime::BackendFactory`] (PJRT executables are
//!   per-thread; on the PJRT path all variants share one *graph* — the
//!   LUT is a runtime operand, so switching precision never recompiles);
//!   executor panics are caught, poisoning only that worker and failing
//!   its batches while [`pipeline::Health`] turns the run's exit non-zero;
//! * resilience ([`resilience`], opt-in via
//!   [`server::InferenceServer::start_resilient`]): per-variant circuit
//!   breakers eject a misbehaving variant from routing and probe it
//!   back; transient executor failures retry with backoff; deadline
//!   slack can hedge a request to a second shard (first success wins,
//!   duplicates discarded); class-routed traffic degrades to the
//!   next-cheapest satisfying variant before it ever sheds; panicked
//!   executors respawn under a bounded restart budget; and executor
//!   pools autoscale on queue-wait pressure — all proven under seeded
//!   [`crate::runtime::FaultPlan`] chaos schedules (rust/tests/chaos.rs);
//! * metrics: per-request latency (enqueue→response) percentiles,
//!   aggregate throughput, and exact accounting — every submitted request
//!   is delivered, shed, or failed, and the three sum to submissions
//!   (property-tested in rust/tests/serving_shard.rs).

pub mod admission;
pub mod batcher;
pub mod cli;
pub mod metrics;
pub mod pipeline;
pub mod resilience;
pub mod router;
pub mod server;
pub mod warmstart;

pub use admission::{Admission, AdmissionController};
pub use metrics::ServerMetrics;
pub use pipeline::Health;
pub use resilience::{AutoscalePolicy, BreakerPolicy, BreakerState, ResilienceConfig};
pub use router::{AccuracyClass, HashRing, RouteDecision, RouteEntry, RoutingTable};
pub use server::{
    Delivery, FailReason, InferenceServer, Request, Response, Route, ServerConfig, SubmitError,
};
pub use warmstart::{plan_profile, profile_for_variant, warm_start_profiles, VariantProfile};
