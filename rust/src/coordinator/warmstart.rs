//! Warm-starting the coordinator's serving tables from the design-point
//! store.
//!
//! The serving path routes requests by multiplier *variant* ("exact",
//! "appro42", "logour", "lm") and wants to report the accuracy/energy
//! trade-off each variant buys — exactly what DSE/PPA characterization
//! produced. Instead of recomputing at boot, the coordinator folds every
//! matching store record into per-family [`VariantProfile`]s: O(disk read)
//! over records that earlier sweeps already paid for.

use std::collections::BTreeMap;

use crate::compile::plan::CompiledPlan;
use crate::store::DesignPointStore;

/// Per-family serving profile assembled from store records.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VariantProfile {
    /// Full family descriptor, e.g. `appro42[yang1x8]`.
    pub family: String,
    /// NMED from the error-metric section, when any record carried one.
    pub nmed: Option<f64>,
    /// Energy per multiply from the PPA section, J.
    pub energy_per_op_j: Option<f64>,
    /// Placed logic area from the PPA section, µm².
    pub logic_area_um2: Option<f64>,
    /// Measured calibration top-1 when the compile pass persisted a
    /// uniform (`compile[f,f,f,f]`) accuracy record for this family.
    pub calib_top1: Option<f64>,
    /// Calibration top-1 **drop vs the uniform-exact baseline** — the
    /// column accuracy-class routing keys on
    /// ([`crate::coordinator::router::RoutingTable`]). `Some(0.0)` for the
    /// exact family; `None` when the store has no exact baseline to
    /// subtract from (an unverifiable drop must not qualify for a class).
    pub calib_drop: Option<f64>,
    /// How many store records were folded into this profile.
    pub records: u64,
}

/// Scan the store and fold every record characterizing a `bits`-bit
/// datapath into per-family profiles. Records carrying an error or PPA
/// section participate directly (functional-yield records label
/// themselves with the netlist instance name, which is not a family);
/// **uniform** compile-accuracy records (`compile[f,f,f,f]`, all four
/// layers the same family) fold their measured calibration top-1 into
/// family `f`, and the uniform-exact record supplies the baseline that
/// turns top-1 into the `calib_drop` column accuracy-class routing
/// consumes. When a family was characterized more than once, the winner
/// is deterministic and preference-ordered, not hash-ordered: the
/// error/accuracy stats with the most samples (exhaustive beats sampled),
/// and the PPA summary with the largest workload — ties broken toward the
/// smaller macro, then by key order (records visit in sorted key order,
/// and only a strictly better rank replaces).
pub fn warm_start_profiles(
    store: &DesignPointStore,
    bits: u32,
) -> BTreeMap<String, VariantProfile> {
    let mut out: BTreeMap<String, VariantProfile> = BTreeMap::new();
    let mut err_rank: BTreeMap<String, u64> = BTreeMap::new();
    let mut ppa_rank: BTreeMap<String, (u64, std::cmp::Reverse<u32>)> = BTreeMap::new();
    // Best (most-sampled) uniform calibration top-1 per inner family.
    let mut acc_rank: BTreeMap<String, u64> = BTreeMap::new();
    let mut acc_top1: BTreeMap<String, f64> = BTreeMap::new();
    store.for_each_record(|_, rec| {
        if rec.bits != bits || rec.family.is_empty() {
            return;
        }
        if let Some(acc) = &rec.accuracy {
            if let Some(inner) = uniform_compile_family(&rec.family) {
                let better = match acc_rank.get(inner) {
                    Some(&r) => acc.samples > r,
                    None => true,
                };
                if better {
                    acc_rank.insert(inner.to_string(), acc.samples);
                    acc_top1.insert(inner.to_string(), acc.top1);
                }
                let p = out.entry(inner.to_string()).or_default();
                p.family = inner.to_string();
                p.records += 1;
                return;
            }
        }
        if rec.error.is_none() && rec.ppa.is_none() {
            return;
        }
        let p = out.entry(rec.family.clone()).or_default();
        p.family = rec.family.clone();
        p.records += 1;
        if let Some(e) = &rec.error {
            let better = match err_rank.get(&rec.family) {
                Some(&r) => e.samples > r,
                None => true,
            };
            if better {
                err_rank.insert(rec.family.clone(), e.samples);
                p.nmed = Some(e.nmed);
            }
        }
        if let Some(ppa) = &rec.ppa {
            let rank = (rec.n_ops, std::cmp::Reverse(rec.rows));
            let better = match ppa_rank.get(&rec.family) {
                Some(r) => rank > *r,
                None => true,
            };
            if better {
                ppa_rank.insert(rec.family.clone(), rank);
                p.energy_per_op_j = Some(ppa.energy_per_op_j);
                p.logic_area_um2 = Some(ppa.logic_area_um2);
            }
        }
    });
    // Attach the calibration columns: measured top-1 plus the drop vs the
    // uniform-exact baseline (exact itself drops 0 by definition; without
    // an exact baseline a drop is unverifiable and stays `None`).
    let exact_top1 = acc_top1.get("exact").copied();
    for (family, p) in out.iter_mut() {
        if let Some(&top1) = acc_top1.get(family) {
            p.calib_top1 = Some(top1);
            p.calib_drop = if family == "exact" {
                Some(0.0)
            } else {
                exact_top1.map(|e| (e - top1).max(0.0))
            };
        }
    }
    out
}

/// `compile[f,f,f,f]` with all four layer families equal → `Some(f)`.
/// Family names never contain commas, so the split is unambiguous even
/// for bracketed names like `appro42[kongx4]`.
fn uniform_compile_family(family: &str) -> Option<&str> {
    let inner = family.strip_prefix("compile[")?.strip_suffix(']')?;
    let mut parts = inner.split(',');
    let first = parts.next()?;
    if first.is_empty() || !parts.all(|p| p == first) {
        return None;
    }
    Some(first)
}

/// The serving profile of a compiled heterogeneous plan: the compile pass
/// already measured everything a warm-start would want, so the plan
/// artifact itself is the profile source (no store scan needed). Energy
/// reports per multiply ([`CompiledPlan::energy_per_op_j`]) to stay in
/// the same unit as the PPA-derived profiles; `nmed` stays empty — a
/// heterogeneous assignment has no single multiplier NMED, its quality
/// metric is the measured calibration drop carried by the plan.
pub fn plan_profile(plan: &CompiledPlan) -> VariantProfile {
    VariantProfile {
        family: format!("plan[{}]", plan.assignment_label()),
        nmed: None,
        energy_per_op_j: Some(plan.energy_per_op_j()),
        logic_area_um2: None,
        calib_top1: Some(plan.plan_top1),
        calib_drop: Some(plan.drop_vs_exact()),
        records: plan.layers.len() as u64,
    }
}

/// Resolve a serving variant name against the profile table. Variant names
/// are short ("lm", "logour"); family descriptors are canonical
/// ("lm-mitchell", "log-our", "appro42[yang1x8]") — matching is on
/// normalized (alphanumeric, lowercase) prefixes, exact matches first.
pub fn profile_for_variant<'a>(
    profiles: &'a BTreeMap<String, VariantProfile>,
    variant: &str,
) -> Option<&'a VariantProfile> {
    let v = norm(variant);
    if v.is_empty() {
        return None;
    }
    profiles
        .iter()
        .map(|(k, p)| (norm(k), p))
        .filter(|(n, _)| *n == v || n.starts_with(&v))
        .min_by_key(|(n, _)| (n != &v, n.len()))
        .map(|(_, p)| p)
}

fn norm(s: &str) -> String {
    s.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .collect::<String>()
        .to_ascii_lowercase()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(families: &[&str]) -> BTreeMap<String, VariantProfile> {
        families
            .iter()
            .map(|f| {
                (
                    f.to_string(),
                    VariantProfile {
                        family: f.to_string(),
                        records: 1,
                        ..Default::default()
                    },
                )
            })
            .collect()
    }

    #[test]
    fn variant_names_resolve_to_canonical_families() {
        let t = table(&["exact", "appro42[yang1x8]", "log-our", "lm-mitchell", "adder-tree"]);
        for (variant, family) in [
            ("exact", "exact"),
            ("appro42", "appro42[yang1x8]"),
            ("logour", "log-our"),
            ("lm", "lm-mitchell"),
        ] {
            assert_eq!(
                profile_for_variant(&t, variant).map(|p| p.family.as_str()),
                Some(family),
                "variant {variant}"
            );
        }
        assert!(profile_for_variant(&t, "unknown").is_none());
        assert!(profile_for_variant(&t, "").is_none());
    }

    #[test]
    fn fold_prefers_best_characterization_and_skips_yield_only_records() {
        use crate::store::{
            DesignPointRecord, DesignPointStore, ErrorStats, KeyBuilder, PpaSummary, YieldStats,
        };
        let dir = std::env::temp_dir().join(format!(
            "openacm_warmstart_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = DesignPointStore::open(&dir).unwrap();
        let err = |nmed: f64, samples: u64| ErrorStats {
            nmed,
            mred: 0.0,
            error_rate: 0.0,
            wce: 0,
            normalized_bias: 0.0,
            samples,
        };
        let ppa = |energy: f64| PpaSummary {
            delay_ns: 5.0,
            logic_area_um2: 1.0,
            sram_area_um2: 1.0,
            pnr_area_um2: 2.0,
            power_w: 1.0,
            energy_per_op_j: energy,
            logic_power_w: 0.5,
            mult_gates: 10,
        };
        // Sampled (few samples) and exhaustive (many) error records, plus
        // PPA at two workload sizes — regardless of hash order, the
        // exhaustive nmed and the larger-workload energy must win.
        let recs = [
            DesignPointRecord {
                family: "log-our".into(),
                bits: 8,
                error: Some(err(0.111, 500)),
                ..Default::default()
            },
            DesignPointRecord {
                family: "log-our".into(),
                bits: 8,
                error: Some(err(0.004, 65536)),
                ..Default::default()
            },
            DesignPointRecord {
                family: "log-our".into(),
                bits: 8,
                rows: 16,
                n_ops: 300,
                ppa: Some(ppa(3e-12)),
                ..Default::default()
            },
            DesignPointRecord {
                family: "log-our".into(),
                bits: 8,
                rows: 16,
                n_ops: 1500,
                ppa: Some(ppa(2e-12)),
                ..Default::default()
            },
            // Yield-only record labelled with a netlist instance name: must
            // not produce a profile entry.
            DesignPointRecord {
                family: "log8_instance".into(),
                bits: 8,
                fyield: Some(YieldStats {
                    pf: 0.1,
                    fom: 1.0,
                    sims: 64,
                    failures: 6,
                }),
                ..Default::default()
            },
        ];
        for (i, rec) in recs.iter().enumerate() {
            let key = KeyBuilder::new("warmstart-test/1").u64(i as u64).finish();
            store.put(key, rec).unwrap();
        }
        let profiles = warm_start_profiles(&store, 8);
        assert_eq!(profiles.len(), 1, "yield-only record must not appear");
        let p = &profiles["log-our"];
        assert_eq!(p.records, 4);
        assert_eq!(p.nmed, Some(0.004), "exhaustive error stats must win");
        assert_eq!(
            p.energy_per_op_j,
            Some(2e-12),
            "larger-workload PPA must win"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn uniform_accuracy_records_fold_into_calibration_columns() {
        use crate::store::{AccuracyStats, DesignPointRecord, DesignPointStore, KeyBuilder};
        let dir = std::env::temp_dir().join(format!(
            "openacm_warmstart_acc_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = DesignPointStore::open(&dir).unwrap();
        let acc = |top1: f64, samples: u64| AccuracyStats { top1, samples };
        let recs = [
            // Uniform-exact baseline.
            ("compile[exact,exact,exact,exact]", acc(0.95, 256)),
            // Uniform approximate: exhaustive beats the sampled rerun.
            ("compile[log-our,log-our,log-our,log-our]", acc(0.93, 256)),
            ("compile[log-our,log-our,log-our,log-our]", acc(0.80, 64)),
            // Heterogeneous assignment: no single family to credit.
            ("compile[log-our,exact,exact,exact]", acc(0.10, 256)),
        ];
        for (i, (family, accuracy)) in recs.iter().enumerate() {
            let key = KeyBuilder::new("warmstart-acc-test/1").u64(i as u64).finish();
            let rec = DesignPointRecord {
                family: family.to_string(),
                bits: 8,
                accuracy: Some(*accuracy),
                ..Default::default()
            };
            store.put(key, &rec).unwrap();
        }
        let profiles = warm_start_profiles(&store, 8);
        assert!(
            profiles.keys().all(|k| !k.starts_with("compile[")),
            "raw compile labels must not leak into the profile table: {:?}",
            profiles.keys().collect::<Vec<_>>()
        );
        let exact = &profiles["exact"];
        assert_eq!(exact.calib_top1, Some(0.95));
        assert_eq!(exact.calib_drop, Some(0.0), "exact drops 0 by definition");
        let lo = &profiles["log-our"];
        assert_eq!(lo.calib_top1, Some(0.93), "most-sampled record must win");
        let drop = lo.calib_drop.expect("drop derivable from the exact baseline");
        assert!((drop - 0.02).abs() < 1e-12, "drop {drop} != 0.95-0.93");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn uniform_family_parser_handles_brackets_and_rejects_mixtures() {
        assert_eq!(
            uniform_compile_family("compile[exact,exact,exact,exact]"),
            Some("exact")
        );
        assert_eq!(
            uniform_compile_family(
                "compile[appro42[kongx4],appro42[kongx4],appro42[kongx4],appro42[kongx4]]"
            ),
            Some("appro42[kongx4]")
        );
        assert_eq!(uniform_compile_family("compile[log-our,exact,exact,exact]"), None);
        assert_eq!(uniform_compile_family("log-our"), None);
        assert_eq!(uniform_compile_family("compile[]"), None);
    }

    #[test]
    fn plan_profile_reports_per_op_energy() {
        use crate::compile::plan::{LayerPlan, PlanLuts};
        use crate::config::spec::MultFamily;
        use crate::nn::model::{LAYER_NAMES, N_LAYERS};
        use std::sync::Arc;

        let plan = CompiledPlan {
            name: "p".into(),
            bits: 8,
            budget_drop: 0.005,
            model_hash: 0,
            calib_hash: 0,
            calib_n: 16,
            exact_top1: 1.0,
            plan_top1: 0.9375,
            exact_energy_per_image_j: 4e-8,
            plan_energy_per_image_j: 2e-8,
            layers: (0..N_LAYERS)
                .map(|i| LayerPlan {
                    layer: LAYER_NAMES[i].to_string(),
                    family: MultFamily::Exact,
                    energy_per_op_j: 2e-12,
                    macs_per_image: 10_000,
                    solo_drop: 0.0,
                })
                .collect(),
        };
        let p = plan_profile(&plan);
        assert_eq!(p.records, N_LAYERS as u64);
        assert!((p.energy_per_op_j.unwrap() - 2e-8 / 40_000.0).abs() < 1e-20);
        assert!(p.family.starts_with("plan["));
        // The profile resolves under the "plan" variant name.
        let mut t = table(&["exact"]);
        t.insert("plan".into(), p);
        let resolved = profile_for_variant(&t, "plan").expect("plan variant resolves");
        assert!(resolved.family.starts_with("plan["));
        // Uniform plans share LUT storage (smoke-checks the Arc sharing).
        let u = PlanLuts::uniform(Arc::new(vec![0i32; 65536]));
        assert!(Arc::ptr_eq(&u.layers[0], &u.layers[2]));
    }

    #[test]
    fn exact_normalized_match_beats_prefix() {
        // "lm-mitchell" and a hypothetical "lm" family: the exact match
        // must win over the longer prefix candidate.
        let t = table(&["lm", "lm-mitchell"]);
        assert_eq!(
            profile_for_variant(&t, "lm").map(|p| p.family.as_str()),
            Some("lm")
        );
    }
}
