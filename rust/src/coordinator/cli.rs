//! `openacm serve` — start the coordinator on the AOT artifacts and drive
//! it with a synthetic request stream (the standalone serving demo; the
//! richer end-to-end driver is examples/e2e_serving.rs).

use anyhow::Result;
use std::path::Path;
use std::time::Duration;

use super::batcher::BatchPolicy;
use super::server::InferenceServer;
use crate::runtime::ArtifactStore;
use crate::util::cli::Args;

pub fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Path::new)
        .map(Path::to_path_buf)
        .unwrap_or_else(ArtifactStore::default_dir);
    let store = ArtifactStore::load(&dir)?;
    let n_requests = args.usize_or("requests", 256)?;
    let policy = BatchPolicy {
        max_batch: args.usize_or("batch", 32)?,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
    };
    println!(
        "starting coordinator: {} variants, batch {} (graph batch {})",
        store.luts.len(),
        policy.max_batch,
        store.batch
    );
    let server = InferenceServer::start(&store, policy)?;
    let variants = server.variants();

    // Drive: round-robin requests across variants from the test set.
    let mut correct = 0usize;
    for i in 0..n_requests {
        let idx = i % store.n_images;
        let variant = &variants[i % variants.len()];
        let resp = server.infer(store.image(idx).to_vec(), variant)?;
        if resp.predicted == store.labels[idx] {
            correct += 1;
        }
    }
    let snap = server.metrics.snapshot();
    println!(
        "completed {} requests ({} correct): p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms, {:.0} req/s, mean batch {:.1}",
        snap.completed, correct, snap.p50_ms, snap.p90_ms, snap.p99_ms, snap.throughput_rps, snap.mean_batch
    );
    server.shutdown();
    Ok(())
}
