//! `openacm serve` — start the sharded coordinator and drive it with a
//! synthetic request stream (the standalone serving demo; the richer
//! end-to-end driver is examples/e2e_serving.rs).
//!
//! Backend dispatch (`--backend native|pjrt|auto`, default `auto`):
//! `pjrt` executes the AOT artifacts and therefore requires `make
//! artifacts`; `native` runs the batched Rust-native quantized CNN — with
//! artifacts it serves the real weights/LUTs/dataset, without them it
//! falls back to a fully synthetic workload (random model, behavioral
//! LUTs, labels = exact-variant predictions). `auto` picks `pjrt` when
//! artifacts exist, `native` otherwise.
//!
//! Serving shape: `--shards N` coordinator shards behind consistent-hash
//! routing, `--slo-ms` the end-to-end latency SLO that deadline-bucket
//! batching closes against. `--classes gold,silver,…` drives part of the
//! stream by accuracy class instead of explicit variant — the router
//! picks the cheapest variant whose store-recorded calibration accuracy
//! satisfies each class (exact fallback otherwise), and the decision
//! table is printed at boot.
//!
//! `--plan FILE.acmplan` additionally serves a compiled heterogeneous
//! plan (`openacm compile`) as the "plan" variant: native per-layer LUT
//! dispatch, profile warm-started from the plan artifact itself.
//!
//! A worker panic during execute is never a silent hang: affected
//! requests fail fast, the event lands in the obs error log, and the
//! command exits non-zero.
//!
//! Resilience knobs (all off by default; see DESIGN.md §"Fault tolerance
//! & elasticity"): `--retries N` retries transient execute failures with
//! backoff, `--hedge MS` hedges requests whose SLO leaves ≥ MS of slack
//! onto a second shard, `--breaker` arms per-variant circuit breakers
//! (and the queue-pressure degradation ladder), `--respawn N` lets a
//! panicked executor respawn up to N times, `--autoscale N` lets each
//! variant's executor pool grow to N workers under queue-wait pressure.
//! `--chaos SEED` swaps the backend for the fixture menu driven by the
//! seeded [`crate::runtime::FaultPlan`] chaos schedule — the serving
//! smoke test for all of the above.

use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use super::batcher::BatchPolicy;
use super::resilience::{AutoscalePolicy, BreakerPolicy, ResilienceConfig};
use super::router::AccuracyClass;
use super::server::{InferenceServer, Route, ServerConfig};
use super::warmstart::{plan_profile, warm_start_profiles};
use crate::bench::harness::sci;
use crate::compile::plan::CompiledPlan;
use crate::nn::eval::argmax;
use crate::nn::model::synthetic_images;
use crate::runtime::backend::{select_backend_with_plan, IMAGE_BYTES};
use crate::runtime::{
    fixture_logits, ArtifactStore, BackendChoice, BackendFactory, FaultPlan, FixtureFactory,
    ServingWorkload,
};
use crate::store::DesignPointStore;
use crate::util::cli::Args;
use crate::util::threadpool::ThreadPool;

/// Reject degenerate serving shapes with a clean, flag-named error
/// before any thread or backend spins up. `autoscale` is `None` when
/// the flag is absent (autoscaling off is a valid shape; a zero worker
/// ceiling is not).
pub(crate) fn validate_serve_shape(
    shards: usize,
    slo_ms: u64,
    max_batch: usize,
    threads: usize,
    autoscale: Option<usize>,
) -> Result<()> {
    if shards == 0 {
        bail!("--shards 0: at least one coordinator shard is required");
    }
    if slo_ms == 0 {
        bail!("--slo-ms 0: the end-to-end latency SLO must be a positive number of milliseconds");
    }
    if max_batch == 0 {
        bail!("--batch 0: a batch must hold at least one request");
    }
    if threads == 0 {
        bail!("--threads 0: the execution pool needs at least one thread");
    }
    if autoscale == Some(0) {
        bail!("--autoscale 0: the worker ceiling must be >= 1 (omit the flag to disable autoscaling)");
    }
    Ok(())
}

pub fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Path::new)
        .map(Path::to_path_buf)
        .unwrap_or_else(ArtifactStore::default_dir);
    let n_requests = args.usize_or("requests", 256)?;
    let max_batch = args.usize_or("batch", 32)?;
    let shards = args.usize_or("shards", 1)?;
    let slo_ms = args.u64_or("slo-ms", 50)?;
    // Resilience knobs, all off by default (the default ResilienceConfig
    // reproduces the legacy pipeline exactly).
    let retries = args.usize_or("retries", 0)? as u32;
    let hedge_ms = args.u64_or("hedge", 0)?;
    let breaker = args.flag("breaker");
    let respawn = args.usize_or("respawn", 0)? as u32;
    let autoscale = match args.get("autoscale") {
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--autoscale wants a worker-ceiling integer, got {s:?}")
        })?),
        None => None,
    };
    let chaos = match args.get("chaos") {
        Some(s) => Some(
            s.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--chaos wants a u64 seed, got {s:?}"))?,
        ),
        None => None,
    };
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    validate_serve_shape(shards, slo_ms, max_batch, threads, autoscale)?;
    // Telemetry sink: structured events stream to <obs-dir>/events.jsonl;
    // `--metrics-every N` additionally prints + flushes a registry
    // snapshot every N driven requests (and once at the end either way).
    let metrics_every = args.usize_or("metrics-every", 0)?;
    let obs_dir = args
        .get("obs-dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(crate::obs::default_dir);
    if let Err(e) = crate::obs::init(&obs_dir) {
        eprintln!("telemetry sink unavailable ({e:#}); events stay in-process");
    }
    let policy = BatchPolicy {
        max_batch,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
        slo: Duration::from_millis(slo_ms.max(1)),
        // Leave a tenth of the SLO (≥1 ms) as execute+respond headroom.
        close_margin: Duration::from_millis((slo_ms / 10).max(1)),
    };
    // Accuracy-class menu: part of the drive stream routes by class when
    // one is given (`--classes gold,silver,0.5%`).
    let classes: Vec<AccuracyClass> = match args.get("classes") {
        Some(spec) => spec
            .split(',')
            .filter(|s| !s.is_empty())
            .map(AccuracyClass::parse)
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let choice = BackendChoice::parse(args.str_or("backend", "auto"))?;
    // A compiled heterogeneous plan (`openacm compile`) serves as its own
    // variant named "plan", executed natively with per-layer LUT dispatch.
    let plan = match args.get("plan") {
        Some(path) => {
            let plan = CompiledPlan::load(Path::new(path))?;
            println!(
                "serving compiled plan {} [{}]: measured drop {:.2}%, {:.1}% energy saving",
                plan.name,
                plan.assignment_label(),
                plan.drop_vs_exact() * 100.0,
                plan.energy_saving() * 100.0
            );
            Some(plan)
        }
        None => None,
    };
    let (factory, workload): (Arc<dyn BackendFactory>, ServingWorkload) = match chaos {
        Some(seed) => {
            // Chaos mode: the deterministic fixture menu driven by a
            // seeded fault schedule — transient error bursts, latency
            // spikes, a panic storm, one slow shard. The same plan the
            // chaos property suite uses (rust/tests/chaos.rs), here as a
            // serving smoke test for the resilience layer.
            if plan.is_some() {
                bail!("--chaos serves the synthetic fixture menu and cannot combine with --plan");
            }
            let menu = ["exact", "appro42", "logour", "lm"];
            let fault = FaultPlan::chaos_default(seed);
            println!(
                "chaos mode: fixture menu {menu:?} under seeded fault plan (seed {seed}): \
                 transient bursts, latency spikes, panic storm, one slow shard"
            );
            let fixture =
                FixtureFactory::new(&menu, max_batch).with_fault_plan(fault);
            let n_images = 64usize;
            let images = synthetic_images(n_images, seed ^ 0xC4A0_5EED);
            let labels = images
                .chunks(IMAGE_BYTES)
                .map(|img| argmax(&fixture_logits("exact", img)))
                .collect();
            (
                Arc::new(fixture) as Arc<dyn BackendFactory>,
                ServingWorkload {
                    images,
                    n_images,
                    labels,
                },
            )
        }
        None => select_backend_with_plan(
            choice,
            &dir,
            max_batch,
            threads,
            args.u64_or("seed", 42)?,
            plan.as_ref().map(|p| ("plan", p)),
        )?,
    };

    println!(
        "starting coordinator: backend {}, {} shards, {} variants, batch {} (capacity {}), SLO {} ms",
        factory.backend_name(),
        shards,
        factory.variants().len(),
        policy.max_batch,
        factory.max_batch(),
        slo_ms
    );
    let res_cfg = ResilienceConfig {
        retries,
        hedge_slack: (hedge_ms > 0).then(|| Duration::from_millis(hedge_ms)),
        breaker: breaker.then(BreakerPolicy::default),
        respawn_budget: respawn,
        autoscale: autoscale.map(|n| AutoscalePolicy {
            max_workers: n,
            ..AutoscalePolicy::default()
        }),
        // The ladder's queue-pressure trigger rides with the breaker
        // flag: re-route class traffic once queue wait eats half the SLO.
        degrade_queue_wait: breaker.then(|| Duration::from_millis(slo_ms) / 2),
        ..ResilienceConfig::default()
    };
    if retries > 0 || hedge_ms > 0 || breaker || respawn > 0 || autoscale.is_some() {
        println!(
            "resilience: retries {retries}, hedge {}, breaker {}, respawn budget {respawn}, \
             autoscale ceiling {}",
            if hedge_ms > 0 {
                format!("≥{hedge_ms} ms slack")
            } else {
                "off".into()
            },
            if breaker { "on (+degrade ladder)" } else { "off" },
            autoscale
                .map(|n| n.to_string())
                .unwrap_or_else(|| "off".into()),
        );
    }
    let mut server = InferenceServer::start_resilient(
        factory,
        ServerConfig {
            shards,
            policy,
            queue_limit: 4096,
        },
        res_cfg,
    )?;

    // Warm-start the serving tables from the design-point store: every
    // variant whose family an earlier DSE/PPA sweep characterized gets its
    // accuracy/energy profile for free (O(disk read), no simulation).
    let store_dir = args
        .get("store")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(DesignPointStore::default_dir);
    // Warm-start is an optimization: any failure here (missing dir,
    // unreadable path, a file where the dir should be) degrades to cold
    // serving tables, never to a failed boot.
    let (mut profiles, store_ok) = match DesignPointStore::open(&store_dir) {
        Ok(dp_store) => (warm_start_profiles(&dp_store, 8), true),
        _ => {
            println!(
                "could not open design-point store at {} — serving tables cold",
                store_dir.display()
            );
            (Default::default(), false)
        }
    };
    // A served plan is its own profile source: the compile pass already
    // measured its accuracy and energy.
    if let Some(plan) = &plan {
        profiles.insert("plan".to_string(), plan_profile(plan));
    }
    server.attach_profiles(profiles);
    let mut warmed = 0usize;
    for v in server.variants() {
        if let Some(p) = server.profile(&v) {
            warmed += 1;
            println!(
                "warm-start {v:>8}: family {:18} nmed {} energy/op {} calib-drop {} ({} records)",
                p.family,
                p.nmed.map(sci).unwrap_or_else(|| "-".into()),
                p.energy_per_op_j
                    .map(|e| format!("{} J", sci(e)))
                    .unwrap_or_else(|| "-".into()),
                p.calib_drop
                    .map(|d| format!("{:.2}%", d * 100.0))
                    .unwrap_or_else(|| "-".into()),
                p.records
            );
        }
    }
    if warmed == 0 && store_ok {
        println!(
            "design-point store {} holds no 8-bit records — serving tables cold \
             (run `openacm dse` to populate)",
            store_dir.display()
        );
    }
    // Print the routing decision per requested class up front, so the
    // accuracy→variant mapping is visible even before traffic.
    for class in &classes {
        match server.routing().select(class) {
            Some(d) => println!(
                "class {:>12} (drop ≤ {:.3}%): -> {}{}",
                class.name,
                class.max_drop * 100.0,
                d.variant,
                if d.fallback { " (exact fallback)" } else { "" }
            ),
            None => println!(
                "class {:>12} (drop ≤ {:.3}%): unroutable (no satisfying variant, no exact)",
                class.name,
                class.max_drop * 100.0
            ),
        }
    }
    let variants = server.variants();

    // SLO burn-rate engine: one tick per metrics interval over the
    // process-cumulative serving counters, publishing `serve.slo.*`
    // gauges and the `[slo]` console line (obs::slo).
    let mut slo_engine = crate::obs::SloEngine::new(crate::obs::SloPolicy::default());
    let slo_input = || crate::obs::slo::SloInput {
        delivered: crate::obs::counter("serve.responses_delivered").value(),
        failed: crate::obs::counter("serve.requests_failed").value(),
        shed: crate::obs::counter("serve.requests_shed").value(),
        delivered_late: crate::obs::counter("serve.delivered_late").value(),
        class_requests: crate::obs::counter("serve.route.class_requests").value(),
        class_fallbacks: crate::obs::counter("serve.route.fallback_exact").value(),
    };

    // Drive: round-robin requests across variants from the workload; with
    // an accuracy-class menu, every other request routes by class
    // instead. Failed deliveries (e.g. an SLO deadline expiring under
    // load) are counted, not fatal — worker health decides the exit code.
    let mut correct = 0usize;
    let mut scored = 0usize;
    let mut failed = 0usize;
    for i in 0..n_requests {
        let idx = i % workload.n_images;
        let route = if !classes.is_empty() && i % 2 == 1 {
            Route::Class(classes[(i / 2) % classes.len()].clone())
        } else {
            Route::Variant(variants[i % variants.len()].clone())
        };
        match server.infer_route(workload.image(idx).to_vec(), route, None) {
            Ok(resp) => {
                scored += 1;
                if resp.predicted == workload.labels[idx] {
                    correct += 1;
                }
            }
            Err(e) => {
                failed += 1;
                if failed <= 3 {
                    eprintln!("request {i} failed: {e:#}");
                }
            }
        }
        if metrics_every > 0 && (i + 1) % metrics_every == 0 {
            let s = server.metrics.snapshot();
            println!(
                "[obs] {}/{n_requests} requests: p50 {:.2} ms p99 {:.2} ms, {:.0} req/s, \
                 in-flight {}",
                i + 1,
                s.p50_ms,
                s.p99_ms,
                s.throughput_rps,
                crate::obs::gauge("serve.in_flight").value()
            );
            let healths = slo_engine.tick_and_publish(slo_input());
            println!("{}", crate::obs::slo::summary_line(&healths));
            server.refresh_resilience_gauges();
            if let Err(e) = crate::obs::flush(&obs_dir) {
                eprintln!("could not flush telemetry snapshot: {e:#}");
            }
        }
    }
    let snap = server.metrics.snapshot();
    println!(
        "completed {} requests ({} correct of {} scored, {} failed): p50 {:.2} ms p90 {:.2} ms \
         p99 {:.2} ms, {:.0} req/s, mean batch {:.1}",
        snap.completed,
        correct,
        scored,
        failed,
        snap.p50_ms,
        snap.p90_ms,
        snap.p99_ms,
        snap.throughput_rps,
        snap.mean_batch
    );
    if snap.degraded > 0 || snap.hedge_discarded > 0 {
        println!(
            "resilience: {} delivered degraded (ladder re-route), {} hedged duplicates discarded, \
             {} executor respawns",
            snap.degraded,
            snap.hedge_discarded,
            crate::obs::counter("serve.executor.respawns").value()
        );
    }
    server.refresh_resilience_gauges();
    let health = server.failure();
    server.shutdown();
    // Final SLO tick after the pipeline drained, so the closing summary
    // and the persisted `serve.slo.*` gauges cover the whole run.
    let healths = slo_engine.tick_and_publish(slo_input());
    println!("{}", crate::obs::slo::summary_line(&healths));
    crate::obs::info(
        "serve",
        "drive complete",
        &[
            ("requests", snap.completed.to_string()),
            ("correct", correct.to_string()),
            ("failed", snap.failed.to_string()),
        ],
    );
    match crate::obs::flush(&obs_dir) {
        Ok(path) => println!("telemetry snapshot: {} (openacm obs snapshot)", path.display()),
        Err(e) => eprintln!("could not flush telemetry snapshot: {e:#}"),
    }
    // Export the tail-sampled request timelines alongside the snapshot.
    if crate::obs::trace_enabled() {
        match crate::obs::trace::export_chrome(&obs_dir) {
            Ok(path) => println!("request timelines: {} (openacm obs trace)", path.display()),
            Err(e) => eprintln!("could not export trace timelines: {e:#}"),
        }
    }
    // A panicked worker must surface as a failed run, never a clean exit.
    if let Some(msg) = health {
        bail!("serving degraded: {msg}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::validate_serve_shape;

    #[test]
    fn zero_shards_is_rejected_with_a_flag_named_error() {
        let e = validate_serve_shape(0, 50, 32, 4, None).unwrap_err();
        assert!(e.to_string().contains("--shards 0"), "{e:#}");
    }

    #[test]
    fn zero_slo_is_rejected_with_a_flag_named_error() {
        let e = validate_serve_shape(1, 0, 32, 4, None).unwrap_err();
        assert!(e.to_string().contains("--slo-ms 0"), "{e:#}");
    }

    #[test]
    fn zero_batch_is_rejected_with_a_flag_named_error() {
        let e = validate_serve_shape(1, 50, 0, 4, None).unwrap_err();
        assert!(e.to_string().contains("--batch 0"), "{e:#}");
    }

    #[test]
    fn zero_threads_is_rejected_with_a_flag_named_error() {
        let e = validate_serve_shape(1, 50, 32, 0, None).unwrap_err();
        assert!(e.to_string().contains("--threads 0"), "{e:#}");
    }

    #[test]
    fn zero_autoscale_ceiling_is_rejected_but_absent_is_fine() {
        let e = validate_serve_shape(1, 50, 32, 4, Some(0)).unwrap_err();
        assert!(e.to_string().contains("--autoscale 0"), "{e:#}");
        assert!(validate_serve_shape(1, 50, 32, 4, None).is_ok());
        assert!(validate_serve_shape(1, 50, 32, 4, Some(3)).is_ok());
    }
}
