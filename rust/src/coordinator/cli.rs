//! `openacm serve` — start the coordinator on the AOT artifacts and drive
//! it with a synthetic request stream (the standalone serving demo; the
//! richer end-to-end driver is examples/e2e_serving.rs).

use anyhow::Result;
use std::path::Path;
use std::time::Duration;

use super::batcher::BatchPolicy;
use super::server::InferenceServer;
use super::warmstart::warm_start_profiles;
use crate::bench::harness::sci;
use crate::runtime::ArtifactStore;
use crate::store::DesignPointStore;
use crate::util::cli::Args;

pub fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Path::new)
        .map(Path::to_path_buf)
        .unwrap_or_else(ArtifactStore::default_dir);
    let store = ArtifactStore::load(&dir)?;
    let n_requests = args.usize_or("requests", 256)?;
    let policy = BatchPolicy {
        max_batch: args.usize_or("batch", 32)?,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
    };
    println!(
        "starting coordinator: {} variants, batch {} (graph batch {})",
        store.luts.len(),
        policy.max_batch,
        store.batch
    );
    let mut server = InferenceServer::start(&store, policy)?;

    // Warm-start the serving tables from the design-point store: every
    // variant whose family an earlier DSE/PPA sweep characterized gets its
    // accuracy/energy profile for free (O(disk read), no simulation).
    let store_dir = args
        .get("store")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(DesignPointStore::default_dir);
    // Warm-start is an optimization: any failure here (missing dir,
    // unreadable path, a file where the dir should be) degrades to cold
    // serving tables, never to a failed boot.
    match DesignPointStore::open(&store_dir) {
        Ok(dp_store) => {
            server.attach_profiles(warm_start_profiles(&dp_store, 8));
            let mut warmed = 0usize;
            for v in server.variants() {
                if let Some(p) = server.profile(&v) {
                    warmed += 1;
                    println!(
                        "warm-start {v:>8}: family {:18} nmed {} energy/op {} ({} records)",
                        p.family,
                        p.nmed.map(sci).unwrap_or_else(|| "-".into()),
                        p.energy_per_op_j
                            .map(|e| format!("{} J", sci(e)))
                            .unwrap_or_else(|| "-".into()),
                        p.records
                    );
                }
            }
            if warmed == 0 {
                println!(
                    "design-point store {} holds no 8-bit records — serving tables cold \
                     (run `openacm dse` to populate)",
                    store_dir.display()
                );
            }
        }
        _ => println!(
            "could not open design-point store at {} — serving tables cold",
            store_dir.display()
        ),
    }
    let variants = server.variants();

    // Drive: round-robin requests across variants from the test set.
    let mut correct = 0usize;
    for i in 0..n_requests {
        let idx = i % store.n_images;
        let variant = &variants[i % variants.len()];
        let resp = server.infer(store.image(idx).to_vec(), variant)?;
        if resp.predicted == store.labels[idx] {
            correct += 1;
        }
    }
    let snap = server.metrics.snapshot();
    println!(
        "completed {} requests ({} correct): p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms, {:.0} req/s, mean batch {:.1}",
        snap.completed, correct, snap.p50_ms, snap.p90_ms, snap.p99_ms, snap.throughput_rps, snap.mean_batch
    );
    server.shutdown();
    Ok(())
}
