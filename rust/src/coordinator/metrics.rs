//! Serving metrics: latency percentiles, throughput, batch-size stats and
//! the per-inference energy estimate.

use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics sink shared by batcher workers.
#[derive(Debug)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<f64>,
    batches: u64,
    batched_requests: u64,
    completed: u64,
}

/// Snapshot for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    pub fn record_batch(&self, batch_size: usize, latencies_us: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_requests += batch_size as u64;
        g.completed += latencies_us.len() as u64;
        g.latencies_us.extend_from_slice(latencies_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        if g.latencies_us.is_empty() {
            return MetricsSnapshot::default();
        }
        let (p50, p90, p99) = crate::util::stats::latency_percentiles(&g.latencies_us);
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            completed: g.completed,
            p50_ms: p50 / 1e3,
            p90_ms: p90 / 1e3,
            p99_ms: p99 / 1e3,
            throughput_rps: g.completed as f64 / secs,
            mean_batch: g.batched_requests as f64 / g.batches.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = ServerMetrics::new();
        m.record_batch(4, &[1000.0, 2000.0, 3000.0, 4000.0]);
        m.record_batch(2, &[5000.0, 6000.0]);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.p50_ms >= 1.0 && s.p50_ms <= 6.0);
        assert!(s.p99_ms >= s.p50_ms);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_ms, 0.0);
    }
}
