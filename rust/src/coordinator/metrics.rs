//! Serving metrics: latency percentiles, throughput and batch-size stats,
//! backed by the `obs::` fixed-memory histograms.
//!
//! The original implementation grew an unbounded `latencies_us: Vec<f64>`
//! behind one mutex and anchored throughput at *construction* time (so a
//! server idle before its first request under-reported rps forever). Now:
//!
//! * latency and batch size land in bounded log-bucketed
//!   [`crate::obs::Histogram`]s — memory is constant for any request
//!   count ([`ServerMetrics::resident_bytes`]; asserted by the soak in
//!   `rust/tests/serving.rs`), the record path is lock-free;
//! * throughput is anchored at the **first recorded request**;
//! * everything mirrors into the process-wide registry
//!   (`serve.latency_us`, `serve.batch_size`, `serve.batches`,
//!   `serve.requests_completed`) so `openacm obs snapshot` sees it.
//!
//! [`MetricsSnapshot`] keeps its exact field set — existing tests and the
//! e2e drivers read it unchanged; percentiles are now the histogram's
//! (≤ 12.5% relative error by bucket geometry).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::obs::{Counter, Histogram};

/// Thread-safe metrics sink shared by batcher workers.
#[derive(Debug)]
pub struct ServerMetrics {
    /// Per-server histograms (a process can run several servers, e.g. the
    /// test soaks; each server's snapshot must only see its own traffic).
    latency_us: Histogram,
    batch_size: Histogram,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Delivered requests that were re-routed down the degradation
    /// ladder (subset of `completed`).
    degraded: AtomicU64,
    /// Hedged duplicates discarded because the sibling copy delivered
    /// first (never client-visible).
    hedge_discarded: AtomicU64,
    /// Throughput anchor: set by the first `record_batch`, not at
    /// construction.
    first_record: OnceLock<Instant>,
    /// Process-wide registry mirrors.
    g_latency_us: Histogram,
    g_batch_size: Histogram,
    g_batches: Counter,
    g_completed: Counter,
    g_failed: Counter,
    g_degraded: Counter,
    g_hedge_discarded: Counter,
}

/// Snapshot for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    /// Admitted requests that ended in a [`crate::coordinator::Delivery::Failed`]
    /// (deadline expired / execute error / worker panic).
    pub failed: u64,
    /// Delivered requests served by a degraded (ladder re-routed)
    /// variant — a subset of `completed`.
    pub degraded: u64,
    /// Hedged duplicate executions discarded after the sibling copy won.
    pub hedge_discarded: u64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            latency_us: Histogram::new(),
            batch_size: Histogram::new(),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            hedge_discarded: AtomicU64::new(0),
            first_record: OnceLock::new(),
            g_latency_us: crate::obs::histogram("serve.latency_us"),
            g_batch_size: crate::obs::histogram("serve.batch_size"),
            g_batches: crate::obs::counter("serve.batches"),
            g_completed: crate::obs::counter("serve.requests_completed"),
            g_failed: crate::obs::counter("serve.requests_failed"),
            g_degraded: crate::obs::counter("serve.degrade.delivered"),
            g_hedge_discarded: crate::obs::counter("serve.hedge.discarded"),
        }
    }

    /// Count delivered requests that rode the degradation ladder.
    pub fn record_degraded(&self, n: usize) {
        self.degraded.fetch_add(n as u64, Ordering::Relaxed);
        self.g_degraded.add(n as u64);
    }

    /// Count hedged duplicates discarded after their sibling delivered.
    pub fn record_hedge_discarded(&self, n: usize) {
        self.hedge_discarded.fetch_add(n as u64, Ordering::Relaxed);
        self.g_hedge_discarded.add(n as u64);
    }

    /// Count admitted requests that terminated in a failure delivery.
    pub fn record_failed(&self, n: usize) {
        self.first_record.get_or_init(Instant::now);
        self.failed.fetch_add(n as u64, Ordering::Relaxed);
        self.g_failed.add(n as u64);
    }

    pub fn failed_total(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn record_batch(&self, batch_size: usize, latencies_us: &[f64]) {
        let pairs: Vec<(f64, u64)> = latencies_us.iter().map(|&l| (l, 0)).collect();
        self.record_batch_exemplars(batch_size, &pairs);
    }

    /// [`Self::record_batch`] with a trace-id exemplar per latency (0 =
    /// untraced): the id lands on the latency histogram bucket the value
    /// falls in, linking percentile reads to concrete requests.
    pub fn record_batch_exemplars(&self, batch_size: usize, latencies_us: &[(f64, u64)]) {
        self.first_record.get_or_init(Instant::now);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(batch_size as u64, Ordering::Relaxed);
        self.completed
            .fetch_add(latencies_us.len() as u64, Ordering::Relaxed);
        self.batch_size.record(batch_size as u64);
        self.g_batch_size.record(batch_size as u64);
        self.g_batches.inc();
        self.g_completed.add(latencies_us.len() as u64);
        for &(l, exemplar) in latencies_us {
            let us = l.max(0.0).round() as u64;
            self.latency_us.record_with_exemplar(us, exemplar);
            self.g_latency_us.record_with_exemplar(us, exemplar);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency_us.snapshot();
        let failed = self.failed.load(Ordering::Relaxed);
        let degraded = self.degraded.load(Ordering::Relaxed);
        let hedge_discarded = self.hedge_discarded.load(Ordering::Relaxed);
        if h.count == 0 {
            return MetricsSnapshot {
                failed,
                degraded,
                hedge_discarded,
                ..MetricsSnapshot::default()
            };
        }
        let secs = self
            .first_record
            .get()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed).max(1);
        MetricsSnapshot {
            completed,
            failed,
            degraded,
            hedge_discarded,
            p50_ms: h.percentile(50.0) as f64 / 1e3,
            p90_ms: h.percentile(90.0) as f64 / 1e3,
            p99_ms: h.percentile(99.0) as f64 / 1e3,
            throughput_rps: completed as f64 / secs,
            mean_batch: self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64,
        }
    }

    /// Bytes held by the latency/batch histograms — constant by
    /// construction whatever the request count (the property the old
    /// `Vec`-based sink lacked; the serving soak asserts it).
    pub fn resident_bytes(&self) -> usize {
        self.latency_us.resident_bytes() + self.batch_size.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = ServerMetrics::new();
        m.record_batch(4, &[1000.0, 2000.0, 3000.0, 4000.0]);
        m.record_batch(2, &[5000.0, 6000.0]);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        assert!(s.p50_ms >= 1.0 && s.p50_ms <= 6.0);
        assert!(s.p99_ms >= s.p50_ms);
        assert!(s.throughput_rps > 0.0);
    }

    #[test]
    fn failed_requests_are_counted_separately_from_completed() {
        let m = ServerMetrics::new();
        m.record_failed(3);
        let s = m.snapshot();
        assert_eq!(s.failed, 3, "failures visible even with no completions");
        assert_eq!(s.completed, 0);
        m.record_batch(2, &[100.0, 100.0]);
        let s = m.snapshot();
        assert_eq!(s.failed, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(m.failed_total(), 3);
    }

    #[test]
    fn exemplar_trace_ids_reach_the_global_latency_histogram() {
        let m = ServerMetrics::new();
        m.record_batch_exemplars(2, &[(1_000.0, 0), (90_000_000.0, 4242)]);
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        let g = crate::obs::snapshot();
        let h = &g.histograms["serve.latency_us"];
        // The untraced (id 0) latency leaves no exemplar; the traced one
        // tags its bucket.
        assert!(
            h.exemplars.iter().any(|&(_, id)| id == 4242),
            "exemplar missing: {:?}",
            h.exemplars
        );
    }

    #[test]
    fn degraded_and_hedge_discards_surface_in_snapshots() {
        let m = ServerMetrics::new();
        m.record_degraded(2);
        m.record_hedge_discarded(1);
        // Visible even before any completion lands.
        let s = m.snapshot();
        assert_eq!(s.degraded, 2);
        assert_eq!(s.hedge_discarded, 1);
        m.record_batch(2, &[100.0, 100.0]);
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.degraded, 2);
        assert_eq!(s.hedge_discarded, 1);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServerMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_ms, 0.0);
    }

    #[test]
    fn memory_is_bounded_for_any_request_count() {
        let m = ServerMetrics::new();
        let before = m.resident_bytes();
        assert!(before > 0);
        for i in 0..10_000 {
            m.record_batch(8, &[(i % 7_000) as f64; 8]);
        }
        assert_eq!(m.resident_bytes(), before, "histograms must not grow");
        let s = m.snapshot();
        assert_eq!(s.completed, 80_000);
        assert!(s.p99_ms >= s.p50_ms);
    }

    #[test]
    fn throughput_is_anchored_at_first_request_not_construction() {
        let m = ServerMetrics::new();
        // Simulate a server idle after construction: with the old
        // construction anchor this sleep would drag rps toward zero.
        std::thread::sleep(std::time::Duration::from_millis(30));
        m.record_batch(2, &[100.0, 100.0]);
        let s = m.snapshot();
        // 2 requests within a few ms of the first record ⇒ far more than
        // the ~60 rps the construction anchor would report.
        assert!(
            s.throughput_rps > 100.0,
            "rps {} should ignore pre-first-request idle time",
            s.throughput_rps
        );
    }
}
