//! Fault-tolerance and elasticity policies for the sharded serving
//! pipeline.
//!
//! This module owns the *decision* half of the resilience layer; the
//! pipeline and server own the *enforcement* half:
//!
//! - [`BreakerPolicy`] / [`BreakerCore`]: a per-variant circuit breaker
//!   (closed → open → half-open) over a sliding window of execution
//!   outcomes. An open breaker ejects the variant from class routing and
//!   fast-fails direct submissions; after a cooldown a bounded number of
//!   probe requests decide whether it re-closes.
//! - [`RestartBudget`]: rate-limited, bounded executor respawns. When
//!   the budget is exhausted the executor poisons itself and reports
//!   through [`super::Health`], exactly like the pre-resilience
//!   fail-fast behavior.
//! - [`AutoscalePolicy`]: per shard×variant executor-thread scaling
//!   driven by the queue-wait pressure EMA fed from the same
//!   measurements as the `serve.queue_wait_us` histogram.
//! - [`ResilienceConfig`]: the umbrella knob set. `Default` disables
//!   every feature, which makes `start_resilient` with a default config
//!   byte-for-byte equivalent to the legacy `start_sharded` pipeline.
//!
//! The state machines here are pure and clock-injected (every method
//! takes `now: Instant`) so the unit tests below drive them
//! deterministically without sleeping.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::obs;

/// Failure-rate circuit breaker knobs.
#[derive(Clone, Copy, Debug)]
pub struct BreakerPolicy {
    /// Sliding window length (number of most-recent outcomes kept).
    pub window: usize,
    /// Minimum outcomes in the window before the breaker may trip.
    pub min_samples: usize,
    /// Trip when `failures / samples >= failure_ratio`.
    pub failure_ratio: f64,
    /// How long an open breaker blocks traffic before probing.
    pub cooldown: Duration,
    /// Probe requests admitted in half-open; all must succeed to
    /// re-close.
    pub probes: u32,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy {
            window: 32,
            min_samples: 8,
            failure_ratio: 0.5,
            cooldown: Duration::from_millis(250),
            probes: 2,
        }
    }
}

/// Breaker state; the numeric form is published as the
/// `serve.breaker.{variant}.state` gauge (0/1/2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    HalfOpen,
    Open,
}

impl BreakerState {
    pub fn gauge(self) -> i64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open => "open",
        }
    }
}

/// Epoch carried by admissions to variants without a configured
/// breaker (and by hedge copies, which borrow no probe slot). Breaker
/// epochs start at 1, so 0 never matches a half-open round.
pub const NO_BREAKER_EPOCH: u64 = 0;

/// Pure breaker state machine. `allow` gates admissions, `on_result`
/// feeds execution outcomes back; both return state transitions so the
/// caller can publish gauges/events exactly once per edge.
///
/// Every admission is stamped with the breaker's current *epoch*
/// (bumped on each state transition). Only outcomes carrying the
/// current half-open epoch count as probe verdicts, so a late result
/// from a batch admitted before the trip can neither spuriously
/// re-close nor re-open the breaker. Probe slots are leak-proof two
/// ways: the caller returns slots whose request never produced an
/// outcome ([`Self::probe_abort`] — shed past admission, expired in
/// queue), and as a backstop a half-open round whose probes all leaked
/// re-arms after another cooldown instead of wedging forever.
pub struct BreakerCore {
    policy: BreakerPolicy,
    state: BreakerState,
    window: VecDeque<bool>,
    failures: usize,
    opened_at: Instant,
    probes_issued: u32,
    probes_ok: u32,
    epoch: u64,
    /// When the current probe round was armed (half-open entry or
    /// re-arm); a round with no verdict by `cooldown` re-arms.
    probe_armed_at: Instant,
    /// When the breaker last left Closed; `None` while Closed. Survives
    /// re-trips so it measures the whole unhealthy episode, not just
    /// the latest open→probe cycle.
    unhealthy_since: Option<Instant>,
}

impl BreakerCore {
    pub fn new(policy: BreakerPolicy, now: Instant) -> Self {
        BreakerCore {
            policy,
            state: BreakerState::Closed,
            window: VecDeque::with_capacity(policy.window.max(1)),
            failures: 0,
            opened_at: now,
            probes_issued: 0,
            probes_ok: 0,
            epoch: 1,
            probe_armed_at: now,
            unhealthy_since: None,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// How long the breaker has been away from Closed (`None` while
    /// Closed) — the `serve.breaker.{variant}.open_ms` gauge.
    pub fn unhealthy_for(&self, now: Instant) -> Option<Duration> {
        self.unhealthy_since.map(|t| now.duration_since(t))
    }

    /// Read-only admission check: would `allow` admit right now? Never
    /// consumes a probe slot or transitions state, which makes it safe
    /// as the degradation ladder's availability predicate (evaluated
    /// for every candidate rung, not just the one selected).
    pub fn would_allow(&self, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => now.duration_since(self.opened_at) >= self.policy.cooldown,
            BreakerState::HalfOpen => {
                self.probes_issued < self.policy.probes.max(1)
                    || now.duration_since(self.probe_armed_at) >= self.policy.cooldown
            }
        }
    }

    /// May a request be admitted to this variant right now? Moves an
    /// open breaker to half-open once the cooldown has elapsed; the
    /// returned transition (if any) is the edge the caller should log.
    /// The returned epoch must ride the admitted request into
    /// [`Self::on_result`] / [`Self::probe_abort`].
    pub fn allow(&mut self, now: Instant) -> (bool, u64, Option<BreakerState>) {
        match self.state {
            BreakerState::Closed => (true, self.epoch, None),
            BreakerState::Open => {
                if now.duration_since(self.opened_at) >= self.policy.cooldown {
                    self.state = BreakerState::HalfOpen;
                    self.epoch += 1;
                    self.probes_issued = 1;
                    self.probes_ok = 0;
                    self.probe_armed_at = now;
                    (true, self.epoch, Some(BreakerState::HalfOpen))
                } else {
                    (false, self.epoch, None)
                }
            }
            BreakerState::HalfOpen => {
                if self.probes_issued < self.policy.probes.max(1) {
                    self.probes_issued += 1;
                    (true, self.epoch, None)
                } else if now.duration_since(self.probe_armed_at) >= self.policy.cooldown {
                    // Every issued probe leaked without a verdict (the
                    // request died where no outcome is reported). Re-arm
                    // the round so the breaker cannot wedge half-open.
                    self.probes_issued = self.probes_ok + 1;
                    self.probe_armed_at = now;
                    (true, self.epoch, None)
                } else {
                    (false, self.epoch, None)
                }
            }
        }
    }

    /// Return an admission slot whose request never produced an outcome
    /// through no fault of the backend (shed past admission, expired in
    /// queue). Only slots from the current half-open round are live.
    pub fn probe_abort(&mut self, epoch: u64) {
        if self.state == BreakerState::HalfOpen
            && epoch == self.epoch
            && self.probes_issued > self.probes_ok
        {
            self.probes_issued -= 1;
        }
    }

    /// Record an execution outcome for a request admitted under
    /// `epoch`. Deadline expiries never reach this path — only genuine
    /// backend failures count against the window.
    pub fn on_result(&mut self, ok: bool, epoch: u64, now: Instant) -> Option<BreakerState> {
        match self.state {
            BreakerState::Closed => {
                if self.window.len() == self.policy.window.max(1) {
                    if let Some(evicted) = self.window.pop_front() {
                        if !evicted {
                            self.failures -= 1;
                        }
                    }
                }
                self.window.push_back(ok);
                if !ok {
                    self.failures += 1;
                }
                let samples = self.window.len();
                if samples >= self.policy.min_samples.max(1)
                    && self.failures as f64 / samples as f64 >= self.policy.failure_ratio
                {
                    self.trip(now);
                    return Some(BreakerState::Open);
                }
                None
            }
            BreakerState::HalfOpen => {
                if epoch != self.epoch {
                    // Stale outcome from a batch admitted before the
                    // trip (or a hedge copy): not a probe verdict.
                    return None;
                }
                if ok {
                    self.probes_ok += 1;
                    if self.probes_ok >= self.policy.probes.max(1) {
                        self.state = BreakerState::Closed;
                        self.epoch += 1;
                        self.window.clear();
                        self.failures = 0;
                        self.unhealthy_since = None;
                        return Some(BreakerState::Closed);
                    }
                    None
                } else {
                    self.trip(now);
                    Some(BreakerState::Open)
                }
            }
            // Late results from batches admitted before the trip.
            BreakerState::Open => None,
        }
    }

    fn trip(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.epoch += 1;
        self.opened_at = now;
        self.unhealthy_since.get_or_insert(now);
        self.window.clear();
        self.failures = 0;
        self.probes_issued = 0;
        self.probes_ok = 0;
    }
}

/// Bounded, rate-limited respawn allowance for a panicked executor.
pub struct RestartBudget {
    budget: u32,
    used: u32,
    min_interval: Duration,
    next_allowed: Option<Instant>,
}

impl RestartBudget {
    pub fn new(budget: u32, min_interval: Duration) -> Self {
        RestartBudget {
            budget,
            used: 0,
            min_interval,
            next_allowed: None,
        }
    }

    /// Ask to respawn at `now`. `Some(delay)` grants the respawn after
    /// waiting `delay` (the rate limit); `None` means the budget is
    /// exhausted and the executor must escalate to `Health`.
    pub fn request(&mut self, now: Instant) -> Option<Duration> {
        if self.used >= self.budget {
            return None;
        }
        self.used += 1;
        let wait = match self.next_allowed {
            Some(t) if t > now => t - now,
            _ => Duration::ZERO,
        };
        self.next_allowed = Some(now + wait + self.min_interval);
        Some(wait)
    }

    pub fn used(&self) -> u32 {
        self.used
    }
}

/// Executor-thread autoscaling knobs for one shard×variant pool.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    /// Upper bound on executor threads per shard×variant pool.
    pub max_workers: usize,
    /// Scale up when the queue-wait EMA exceeds this.
    pub scale_up_wait: Duration,
    /// Scale down when the queue-wait EMA drops below this.
    pub scale_down_wait: Duration,
    /// Controller evaluation period.
    pub tick: Duration,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            max_workers: 4,
            scale_up_wait: Duration::from_millis(2),
            scale_down_wait: Duration::from_micros(200),
            tick: Duration::from_millis(10),
        }
    }
}

/// Pure scaling decision: `Some(new_target)` when the pool should grow
/// or shrink by one worker, `None` to hold.
pub fn autoscale_decision(
    policy: &AutoscalePolicy,
    current: usize,
    queue_wait: Duration,
) -> Option<usize> {
    if queue_wait >= policy.scale_up_wait && current < policy.max_workers.max(1) {
        Some(current + 1)
    } else if queue_wait <= policy.scale_down_wait && current > 1 {
        Some(current - 1)
    } else {
        None
    }
}

/// Umbrella configuration for the resilience layer. The default
/// disables everything, reproducing the legacy pipeline exactly
/// (first worker panic poisons the executor and reports `Health`).
#[derive(Clone, Copy, Debug)]
pub struct ResilienceConfig {
    /// Retries per batch for transient executor failures (0 = off).
    pub retries: u32,
    /// Base backoff between retries (attempt N sleeps `N * backoff`).
    pub retry_backoff: Duration,
    /// Hedge a request to a second shard when its deadline slack
    /// exceeds this threshold (`None` = hedging off). First successful
    /// result wins; duplicates are discarded and counted.
    pub hedge_slack: Option<Duration>,
    /// Per-variant circuit breakers (`None` = off).
    pub breaker: Option<BreakerPolicy>,
    /// Respawns allowed per executor before escalating to `Health`
    /// (0 = legacy fail-fast poison on first panic).
    pub respawn_budget: u32,
    /// Minimum spacing between respawns of the same executor.
    pub respawn_min_interval: Duration,
    /// Executor autoscaling (`None` = fixed single worker per pool).
    pub autoscale: Option<AutoscalePolicy>,
    /// Degradation-ladder pressure threshold: a variant whose queue-wait
    /// EMA exceeds this is skipped by class routing (`None` = off).
    pub degrade_queue_wait: Option<Duration>,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            retries: 0,
            retry_backoff: Duration::from_micros(500),
            hedge_slack: None,
            breaker: None,
            respawn_budget: 0,
            respawn_min_interval: Duration::from_millis(10),
            autoscale: None,
            degrade_queue_wait: None,
        }
    }
}

/// Queue-wait pressure EMA (µs), updated lock-free from the batcher.
pub struct PressureEwma(AtomicU64);

impl PressureEwma {
    pub fn new() -> Self {
        PressureEwma(AtomicU64::new(0))
    }

    /// Fold one queue-wait sample into the EMA (α = 1/8). CAS loop: the
    /// batcher observes while the scaler decays, and a plain
    /// load-compute-store would lose whichever update raced.
    pub fn observe(&self, us: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
            Some(if old == 0 { us } else { old - old / 8 + us / 8 })
        });
    }

    /// Decay toward zero so an idle pool scales back down. Saturates:
    /// below 4µs the quarter-decay would round to zero and leave a
    /// permanent residual, so small values snap straight to 0.
    pub fn decay(&self) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
            Some(if old < 4 { 0 } else { old - old / 4 })
        });
    }

    pub fn us(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for PressureEwma {
    fn default() -> Self {
        PressureEwma::new()
    }
}

struct VariantBreaker {
    core: Mutex<BreakerCore>,
    state_gauge: obs::Gauge,
    /// `serve.breaker.{variant}.open_ms`: how long the breaker has been
    /// away from Closed (refreshed on metrics ticks, 0 while Closed) so
    /// `obs health` can tell a normal cooldown from a stuck breaker.
    open_ms: obs::Gauge,
}

/// Shared runtime state for the resilience layer: per-variant breakers
/// plus per-shard×variant queue-wait pressure. One instance per server,
/// shared by the submit path, the batchers, and the autoscale
/// controllers.
pub(crate) struct ResilienceRuntime {
    pub cfg: ResilienceConfig,
    breakers: BTreeMap<String, VariantBreaker>,
    /// variant → one EMA per shard.
    pressure: BTreeMap<String, Vec<PressureEwma>>,
    opened: obs::Counter,
    reclosed: obs::Counter,
    probing: obs::Counter,
}

impl ResilienceRuntime {
    pub fn new(cfg: ResilienceConfig, variants: &[String], shards: usize) -> Self {
        let now = Instant::now();
        let mut breakers = BTreeMap::new();
        if let Some(policy) = cfg.breaker {
            // `obs health` scales its stuck-open threshold off this.
            obs::gauge("serve.breaker.cooldown_ms").set(policy.cooldown.as_millis() as i64);
            for v in variants {
                let state_gauge = obs::gauge(&format!("serve.breaker.{v}.state"));
                state_gauge.set(0);
                let open_ms = obs::gauge(&format!("serve.breaker.{v}.open_ms"));
                open_ms.set(0);
                breakers.insert(
                    v.clone(),
                    VariantBreaker {
                        core: Mutex::new(BreakerCore::new(policy, now)),
                        state_gauge,
                        open_ms,
                    },
                );
            }
        }
        let pressure = variants
            .iter()
            .map(|v| {
                (
                    v.clone(),
                    (0..shards.max(1)).map(|_| PressureEwma::new()).collect(),
                )
            })
            .collect();
        ResilienceRuntime {
            cfg,
            breakers,
            pressure,
            opened: obs::counter("serve.breaker.opened"),
            reclosed: obs::counter("serve.breaker.reclosed"),
            probing: obs::counter("serve.breaker.probes"),
        }
    }

    /// Are any breakers configured? Lets the responder skip collecting
    /// per-request epochs on the default (resilience-off) path.
    pub fn breakers_on(&self) -> bool {
        !self.breakers.is_empty()
    }

    /// Probe-consuming breaker admission. `Some(epoch)` admits — the
    /// epoch must ride the request so its outcome (or abort) is matched
    /// to the breaker state that admitted it ([`NO_BREAKER_EPOCH`] when
    /// no breaker is configured); `None` means the breaker is blocking
    /// this variant right now. Call this exactly once, for the variant
    /// actually being enqueued — routing candidates are screened with
    /// the read-only [`Self::routable`].
    pub fn admit(&self, variant: &str) -> Option<u64> {
        let Some(b) = self.breakers.get(variant) else {
            return Some(NO_BREAKER_EPOCH);
        };
        let mut core = b.core.lock().unwrap();
        let (ok, epoch, transition) = core.allow(Instant::now());
        if let Some(state) = transition {
            self.publish_transition(variant, b, state);
        }
        ok.then_some(epoch)
    }

    /// Return a probe slot for an admission that will never produce an
    /// execution outcome (shed past admission, ingress full, expired in
    /// queue) so the half-open round can re-issue it.
    pub fn probe_abort(&self, variant: &str, epoch: u64) {
        if epoch == NO_BREAKER_EPOCH {
            return;
        }
        if let Some(b) = self.breakers.get(variant) {
            b.core.lock().unwrap().probe_abort(epoch);
        }
    }

    /// [`Self::probe_abort`] over a whole deadline-expired batch.
    pub fn probe_abort_batch(&self, variant: &str, epochs: &[u64]) {
        let Some(b) = self.breakers.get(variant) else {
            return;
        };
        let mut core = b.core.lock().unwrap();
        for &e in epochs {
            if e != NO_BREAKER_EPOCH {
                core.probe_abort(e);
            }
        }
    }

    /// Is this variant's queue-wait pressure above the degradation
    /// threshold on any shard?
    pub fn overloaded(&self, variant: &str) -> bool {
        let Some(limit) = self.cfg.degrade_queue_wait else {
            return false;
        };
        let limit_us = limit.as_micros() as u64;
        self.pressure
            .get(variant)
            .map(|per_shard| per_shard.iter().any(|p| p.us() > limit_us))
            .unwrap_or(false)
    }

    /// Degradation-ladder availability: breaker would admit and
    /// pressure is under the threshold. Strictly read-only — routing
    /// evaluates this for every candidate rung, so it must not consume
    /// probe slots (the selected variant consumes one via
    /// [`Self::admit`]).
    pub fn routable(&self, variant: &str) -> bool {
        let breaker_ok = match self.breakers.get(variant) {
            None => true,
            Some(b) => b.core.lock().unwrap().would_allow(Instant::now()),
        };
        breaker_ok && !self.overloaded(variant)
    }

    /// Feed one batch's execution outcomes for `variant` back into its
    /// breaker; `epochs` are the admission epochs the requests carried.
    pub fn on_batch_outcome(&self, variant: &str, ok: bool, epochs: &[u64]) {
        let Some(b) = self.breakers.get(variant) else {
            return;
        };
        let mut core = b.core.lock().unwrap();
        for &e in epochs {
            if let Some(state) = core.on_result(ok, e, Instant::now()) {
                self.publish_transition(variant, b, state);
            }
        }
    }

    /// Re-publish time-derived breaker gauges
    /// (`serve.breaker.{variant}.open_ms`) — called from the serve
    /// CLI's metrics ticks and at exit, right before snapshot flushes.
    pub fn refresh_gauges(&self) {
        let now = Instant::now();
        for (_, b) in &self.breakers {
            let ms = b
                .core
                .lock()
                .unwrap()
                .unhealthy_for(now)
                .map(|d| d.as_millis() as i64)
                .unwrap_or(0);
            b.open_ms.set(ms);
        }
    }

    fn publish_transition(&self, variant: &str, b: &VariantBreaker, state: BreakerState) {
        b.state_gauge.set(state.gauge());
        let fields = [("variant", variant.to_string())];
        match state {
            BreakerState::Open => {
                self.opened.inc();
                obs::warn("serve", "circuit breaker opened", &fields);
            }
            BreakerState::HalfOpen => {
                self.probing.inc();
                obs::info("serve", "circuit breaker probing (half-open)", &fields);
            }
            BreakerState::Closed => {
                self.reclosed.inc();
                obs::info("serve", "circuit breaker re-closed", &fields);
            }
        }
    }

    pub fn note_queue_wait(&self, shard: usize, variant: &str, us: u64) {
        if let Some(p) = self.pressure.get(variant).and_then(|v| v.get(shard)) {
            p.observe(us);
        }
    }

    pub fn queue_wait_us(&self, shard: usize, variant: &str) -> u64 {
        self.pressure
            .get(variant)
            .and_then(|v| v.get(shard))
            .map(|p| p.us())
            .unwrap_or(0)
    }

    pub fn decay_pressure(&self, shard: usize, variant: &str) {
        if let Some(p) = self.pressure.get(variant).and_then(|v| v.get(shard)) {
            p.decay();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BreakerPolicy {
        BreakerPolicy {
            window: 8,
            min_samples: 4,
            failure_ratio: 0.5,
            cooldown: Duration::from_millis(100),
            probes: 2,
        }
    }

    /// Trip a fresh breaker with 4 closed-epoch failures.
    fn tripped(t0: Instant) -> BreakerCore {
        let mut b = BreakerCore::new(policy(), t0);
        for _ in 0..4 {
            b.on_result(false, 1, t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        b
    }

    #[test]
    fn breaker_trips_after_failure_ratio_over_min_samples() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        assert_eq!(b.state(), BreakerState::Closed);
        // Three failures: below min_samples, still closed.
        for _ in 0..3 {
            assert_eq!(b.on_result(false, 1, t0), None);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0).0);
        // Fourth failure reaches min_samples at 100% failure rate.
        assert_eq!(b.on_result(false, 1, t0), Some(BreakerState::Open));
        assert!(!b.allow(t0).0);
    }

    #[test]
    fn breaker_stays_closed_under_half_failure_window() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        // Alternate ok/fail: ratio sits at 0.5 boundary only on the
        // fail edges; feed mostly-ok traffic and it must never trip.
        for i in 0..64 {
            let ok = i % 3 != 0; // 1/3 failures < 0.5 ratio
            assert_eq!(b.on_result(ok, 1, t0), None, "tripped at sample {i}");
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_probes_back_to_closed_after_cooldown() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        // Before cooldown: blocked.
        let (ok, _, tr) = b.allow(t0 + Duration::from_millis(50));
        assert!(!ok && tr.is_none());
        // After cooldown: half-open, first probe admitted.
        let t1 = t0 + Duration::from_millis(150);
        let (ok, e, tr) = b.allow(t1);
        assert!(ok);
        assert_eq!(tr, Some(BreakerState::HalfOpen));
        // Second probe admitted, third blocked (probes = 2).
        assert!(b.allow(t1).0);
        assert!(!b.allow(t1).0);
        // Both probes succeed → re-closed.
        assert_eq!(b.on_result(true, e, t1), None);
        assert_eq!(b.on_result(true, e, t1), Some(BreakerState::Closed));
        assert!(b.allow(t1).0);
        assert_eq!(b.unhealthy_for(t1), None);
    }

    #[test]
    fn breaker_reopens_when_probe_fails() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        let t1 = t0 + Duration::from_millis(150);
        let (ok, e, _) = b.allow(t1);
        assert!(ok);
        assert_eq!(b.on_result(false, e, t1), Some(BreakerState::Open));
        // Cooldown restarts from the re-open instant.
        assert!(!b.allow(t1 + Duration::from_millis(50)).0);
        assert!(b.allow(t1 + Duration::from_millis(150)).0);
    }

    #[test]
    fn breaker_window_slides_old_failures_out() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        // 3 failures then a long run of successes: the failures age out
        // of the window and the ratio can no longer trip.
        for _ in 0..3 {
            b.on_result(false, 1, t0);
        }
        for _ in 0..8 {
            assert_eq!(b.on_result(true, 1, t0), None);
        }
        // One more failure: window is now 7 ok + 1 fail — stays closed.
        assert_eq!(b.on_result(false, 1, t0), None);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn would_allow_never_consumes_probes_or_transitions() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        let t1 = t0 + Duration::from_millis(150);
        // Post-cooldown routability checks leave the breaker Open.
        for _ in 0..100 {
            assert!(b.would_allow(t1));
        }
        assert_eq!(b.state(), BreakerState::Open);
        // Entering half-open, repeated checks don't eat probe slots:
        // both real probe admissions still go through.
        assert!(b.allow(t1).0);
        for _ in 0..100 {
            assert!(b.would_allow(t1));
        }
        assert!(b.allow(t1).0);
        assert!(!b.allow(t1).0);
        assert!(!b.would_allow(t1));
    }

    #[test]
    fn half_open_ignores_stale_epoch_results() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        let t1 = t0 + Duration::from_millis(150);
        let (ok, e, _) = b.allow(t1);
        assert!(ok);
        // Late results from pre-trip (epoch 1) batches: neither a stale
        // success nor a stale failure moves the probe round.
        assert_eq!(b.on_result(true, 1, t1), None);
        assert_eq!(b.on_result(true, 1, t1), None);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.on_result(false, 1, t1), None);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // Genuine probe outcomes still close it.
        assert!(b.allow(t1).0);
        assert_eq!(b.on_result(true, e, t1), None);
        assert_eq!(b.on_result(true, e, t1), Some(BreakerState::Closed));
    }

    #[test]
    fn probe_abort_returns_the_slot_for_reissue() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        let t1 = t0 + Duration::from_millis(150);
        let (ok, e, _) = b.allow(t1);
        assert!(ok);
        assert!(b.allow(t1).0);
        assert!(!b.allow(t1).0, "both probe slots issued");
        // One admission dies without an outcome (shed / expired): its
        // abort frees the slot for another probe immediately.
        b.probe_abort(e);
        assert!(b.allow(t1).0);
        assert!(!b.allow(t1).0);
        // Stale-epoch aborts are ignored.
        b.probe_abort(e - 1);
        assert!(!b.allow(t1).0);
    }

    #[test]
    fn half_open_rearms_probes_after_cooldown_instead_of_wedging() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        let t1 = t0 + Duration::from_millis(150);
        let (ok, e, _) = b.allow(t1);
        assert!(ok);
        assert!(b.allow(t1).0);
        // Both probes leak (no outcome ever arrives). Within the
        // cooldown the round is blocked…
        assert!(!b.allow(t1 + Duration::from_millis(50)).0);
        // …but another cooldown later it re-arms and admits again, so
        // the breaker can never wedge half-open.
        let t2 = t1 + Duration::from_millis(150);
        let (ok, e2, _) = b.allow(t2);
        assert!(ok, "leaked probe round must re-arm after cooldown");
        assert_eq!(e, e2, "re-arm stays in the same half-open epoch");
        assert_eq!(b.on_result(true, e2, t2), None);
        assert!(b.allow(t2).0);
        assert_eq!(b.on_result(true, e2, t2), Some(BreakerState::Closed));
    }

    #[test]
    fn unhealthy_duration_spans_retrip_episodes() {
        let t0 = Instant::now();
        let mut b = tripped(t0);
        assert_eq!(
            b.unhealthy_for(t0 + Duration::from_millis(10)),
            Some(Duration::from_millis(10))
        );
        // Failed probe re-trips: the episode clock keeps its origin.
        let t1 = t0 + Duration::from_millis(150);
        let (_, e, _) = b.allow(t1);
        b.on_result(false, e, t1);
        assert_eq!(
            b.unhealthy_for(t0 + Duration::from_millis(500)),
            Some(Duration::from_millis(500))
        );
        // Re-close clears it.
        let t2 = t1 + Duration::from_millis(150);
        let (_, e, _) = b.allow(t2);
        b.on_result(true, e, t2);
        b.allow(t2);
        b.on_result(true, e, t2);
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.unhealthy_for(t2), None);
    }

    #[test]
    fn restart_budget_grants_then_exhausts() {
        let t0 = Instant::now();
        let mut rb = RestartBudget::new(2, Duration::from_millis(10));
        assert_eq!(rb.request(t0), Some(Duration::ZERO));
        // Immediate second request is rate-limited to the interval.
        let wait = rb.request(t0).expect("second respawn within budget");
        assert_eq!(wait, Duration::from_millis(10));
        // Third request: exhausted.
        assert_eq!(rb.request(t0), None);
        assert_eq!(rb.used(), 2);
    }

    #[test]
    fn restart_budget_zero_always_escalates() {
        let mut rb = RestartBudget::new(0, Duration::ZERO);
        assert_eq!(rb.request(Instant::now()), None);
    }

    #[test]
    fn restart_budget_spaced_requests_wait_nothing() {
        let t0 = Instant::now();
        let mut rb = RestartBudget::new(3, Duration::from_millis(10));
        assert_eq!(rb.request(t0), Some(Duration::ZERO));
        assert_eq!(
            rb.request(t0 + Duration::from_millis(20)),
            Some(Duration::ZERO)
        );
    }

    #[test]
    fn autoscale_decision_grows_shrinks_and_holds() {
        let p = AutoscalePolicy {
            max_workers: 3,
            scale_up_wait: Duration::from_millis(2),
            scale_down_wait: Duration::from_micros(200),
            tick: Duration::from_millis(10),
        };
        // Pressure above the high watermark grows, up to the cap.
        assert_eq!(autoscale_decision(&p, 1, Duration::from_millis(5)), Some(2));
        assert_eq!(autoscale_decision(&p, 3, Duration::from_millis(5)), None);
        // Idle pool shrinks, but never below one worker.
        assert_eq!(
            autoscale_decision(&p, 2, Duration::from_micros(100)),
            Some(1)
        );
        assert_eq!(autoscale_decision(&p, 1, Duration::from_micros(100)), None);
        // In the hysteresis band: hold.
        assert_eq!(autoscale_decision(&p, 2, Duration::from_millis(1)), None);
    }

    #[test]
    fn pressure_ewma_tracks_and_decays() {
        let p = PressureEwma::new();
        assert_eq!(p.us(), 0);
        p.observe(8000);
        assert_eq!(p.us(), 8000);
        p.observe(8000);
        assert_eq!(p.us(), 8000);
        p.observe(0);
        assert!(p.us() < 8000);
        let before = p.us();
        p.decay();
        assert!(p.us() < before);
        // Decay saturates all the way to 0 (no sub-4µs residual).
        for _ in 0..64 {
            p.decay();
        }
        assert_eq!(p.us(), 0);
    }

    #[test]
    fn default_config_disables_every_feature() {
        let cfg = ResilienceConfig::default();
        assert_eq!(cfg.retries, 0);
        assert!(cfg.hedge_slack.is_none());
        assert!(cfg.breaker.is_none());
        assert_eq!(cfg.respawn_budget, 0);
        assert!(cfg.autoscale.is_none());
        assert!(cfg.degrade_queue_wait.is_none());
    }
}
