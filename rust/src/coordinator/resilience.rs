//! Fault-tolerance and elasticity policies for the sharded serving
//! pipeline.
//!
//! This module owns the *decision* half of the resilience layer; the
//! pipeline and server own the *enforcement* half:
//!
//! - [`BreakerPolicy`] / [`BreakerCore`]: a per-variant circuit breaker
//!   (closed → open → half-open) over a sliding window of execution
//!   outcomes. An open breaker ejects the variant from class routing and
//!   fast-fails direct submissions; after a cooldown a bounded number of
//!   probe requests decide whether it re-closes.
//! - [`RestartBudget`]: rate-limited, bounded executor respawns. When
//!   the budget is exhausted the executor poisons itself and reports
//!   through [`super::Health`], exactly like the pre-resilience
//!   fail-fast behavior.
//! - [`AutoscalePolicy`]: per shard×variant executor-thread scaling
//!   driven by the queue-wait pressure EMA fed from the same
//!   measurements as the `serve.queue_wait_us` histogram.
//! - [`ResilienceConfig`]: the umbrella knob set. `Default` disables
//!   every feature, which makes `start_resilient` with a default config
//!   byte-for-byte equivalent to the legacy `start_sharded` pipeline.
//!
//! The state machines here are pure and clock-injected (every method
//! takes `now: Instant`) so the unit tests below drive them
//! deterministically without sleeping.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::obs;

/// Failure-rate circuit breaker knobs.
#[derive(Clone, Copy, Debug)]
pub struct BreakerPolicy {
    /// Sliding window length (number of most-recent outcomes kept).
    pub window: usize,
    /// Minimum outcomes in the window before the breaker may trip.
    pub min_samples: usize,
    /// Trip when `failures / samples >= failure_ratio`.
    pub failure_ratio: f64,
    /// How long an open breaker blocks traffic before probing.
    pub cooldown: Duration,
    /// Probe requests admitted in half-open; all must succeed to
    /// re-close.
    pub probes: u32,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy {
            window: 32,
            min_samples: 8,
            failure_ratio: 0.5,
            cooldown: Duration::from_millis(250),
            probes: 2,
        }
    }
}

/// Breaker state; the numeric form is published as the
/// `serve.breaker.{variant}.state` gauge (0/1/2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    HalfOpen,
    Open,
}

impl BreakerState {
    pub fn gauge(self) -> i64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open => "open",
        }
    }
}

/// Pure breaker state machine. `allow` gates admissions, `on_result`
/// feeds execution outcomes back; both return state transitions so the
/// caller can publish gauges/events exactly once per edge.
pub struct BreakerCore {
    policy: BreakerPolicy,
    state: BreakerState,
    window: VecDeque<bool>,
    failures: usize,
    opened_at: Instant,
    probes_issued: u32,
    probes_ok: u32,
}

impl BreakerCore {
    pub fn new(policy: BreakerPolicy, now: Instant) -> Self {
        BreakerCore {
            policy,
            state: BreakerState::Closed,
            window: VecDeque::with_capacity(policy.window.max(1)),
            failures: 0,
            opened_at: now,
            probes_issued: 0,
            probes_ok: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// May a request be admitted to this variant right now? Moves an
    /// open breaker to half-open once the cooldown has elapsed; the
    /// returned transition (if any) is the edge the caller should log.
    pub fn allow(&mut self, now: Instant) -> (bool, Option<BreakerState>) {
        match self.state {
            BreakerState::Closed => (true, None),
            BreakerState::Open => {
                if now.duration_since(self.opened_at) >= self.policy.cooldown {
                    self.state = BreakerState::HalfOpen;
                    self.probes_issued = 1;
                    self.probes_ok = 0;
                    (true, Some(BreakerState::HalfOpen))
                } else {
                    (false, None)
                }
            }
            BreakerState::HalfOpen => {
                if self.probes_issued < self.policy.probes.max(1) {
                    self.probes_issued += 1;
                    (true, None)
                } else {
                    (false, None)
                }
            }
        }
    }

    /// Record an execution outcome. Deadline expiries never reach this
    /// path — only genuine backend failures count against the window.
    pub fn on_result(&mut self, ok: bool, now: Instant) -> Option<BreakerState> {
        match self.state {
            BreakerState::Closed => {
                if self.window.len() == self.policy.window.max(1) {
                    if let Some(evicted) = self.window.pop_front() {
                        if !evicted {
                            self.failures -= 1;
                        }
                    }
                }
                self.window.push_back(ok);
                if !ok {
                    self.failures += 1;
                }
                let samples = self.window.len();
                if samples >= self.policy.min_samples.max(1)
                    && self.failures as f64 / samples as f64 >= self.policy.failure_ratio
                {
                    self.trip(now);
                    return Some(BreakerState::Open);
                }
                None
            }
            BreakerState::HalfOpen => {
                if ok {
                    self.probes_ok += 1;
                    if self.probes_ok >= self.policy.probes.max(1) {
                        self.state = BreakerState::Closed;
                        self.window.clear();
                        self.failures = 0;
                        return Some(BreakerState::Closed);
                    }
                    None
                } else {
                    self.trip(now);
                    Some(BreakerState::Open)
                }
            }
            // Late results from batches admitted before the trip.
            BreakerState::Open => None,
        }
    }

    fn trip(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.opened_at = now;
        self.window.clear();
        self.failures = 0;
        self.probes_issued = 0;
        self.probes_ok = 0;
    }
}

/// Bounded, rate-limited respawn allowance for a panicked executor.
pub struct RestartBudget {
    budget: u32,
    used: u32,
    min_interval: Duration,
    next_allowed: Option<Instant>,
}

impl RestartBudget {
    pub fn new(budget: u32, min_interval: Duration) -> Self {
        RestartBudget {
            budget,
            used: 0,
            min_interval,
            next_allowed: None,
        }
    }

    /// Ask to respawn at `now`. `Some(delay)` grants the respawn after
    /// waiting `delay` (the rate limit); `None` means the budget is
    /// exhausted and the executor must escalate to `Health`.
    pub fn request(&mut self, now: Instant) -> Option<Duration> {
        if self.used >= self.budget {
            return None;
        }
        self.used += 1;
        let wait = match self.next_allowed {
            Some(t) if t > now => t - now,
            _ => Duration::ZERO,
        };
        self.next_allowed = Some(now + wait + self.min_interval);
        Some(wait)
    }

    pub fn used(&self) -> u32 {
        self.used
    }
}

/// Executor-thread autoscaling knobs for one shard×variant pool.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    /// Upper bound on executor threads per shard×variant pool.
    pub max_workers: usize,
    /// Scale up when the queue-wait EMA exceeds this.
    pub scale_up_wait: Duration,
    /// Scale down when the queue-wait EMA drops below this.
    pub scale_down_wait: Duration,
    /// Controller evaluation period.
    pub tick: Duration,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            max_workers: 4,
            scale_up_wait: Duration::from_millis(2),
            scale_down_wait: Duration::from_micros(200),
            tick: Duration::from_millis(10),
        }
    }
}

/// Pure scaling decision: `Some(new_target)` when the pool should grow
/// or shrink by one worker, `None` to hold.
pub fn autoscale_decision(
    policy: &AutoscalePolicy,
    current: usize,
    queue_wait: Duration,
) -> Option<usize> {
    if queue_wait >= policy.scale_up_wait && current < policy.max_workers.max(1) {
        Some(current + 1)
    } else if queue_wait <= policy.scale_down_wait && current > 1 {
        Some(current - 1)
    } else {
        None
    }
}

/// Umbrella configuration for the resilience layer. The default
/// disables everything, reproducing the legacy pipeline exactly
/// (first worker panic poisons the executor and reports `Health`).
#[derive(Clone, Copy, Debug)]
pub struct ResilienceConfig {
    /// Retries per batch for transient executor failures (0 = off).
    pub retries: u32,
    /// Base backoff between retries (attempt N sleeps `N * backoff`).
    pub retry_backoff: Duration,
    /// Hedge a request to a second shard when its deadline slack
    /// exceeds this threshold (`None` = hedging off). First successful
    /// result wins; duplicates are discarded and counted.
    pub hedge_slack: Option<Duration>,
    /// Per-variant circuit breakers (`None` = off).
    pub breaker: Option<BreakerPolicy>,
    /// Respawns allowed per executor before escalating to `Health`
    /// (0 = legacy fail-fast poison on first panic).
    pub respawn_budget: u32,
    /// Minimum spacing between respawns of the same executor.
    pub respawn_min_interval: Duration,
    /// Executor autoscaling (`None` = fixed single worker per pool).
    pub autoscale: Option<AutoscalePolicy>,
    /// Degradation-ladder pressure threshold: a variant whose queue-wait
    /// EMA exceeds this is skipped by class routing (`None` = off).
    pub degrade_queue_wait: Option<Duration>,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            retries: 0,
            retry_backoff: Duration::from_micros(500),
            hedge_slack: None,
            breaker: None,
            respawn_budget: 0,
            respawn_min_interval: Duration::from_millis(10),
            autoscale: None,
            degrade_queue_wait: None,
        }
    }
}

/// Queue-wait pressure EMA (µs), updated lock-free from the batcher.
pub struct PressureEwma(AtomicU64);

impl PressureEwma {
    pub fn new() -> Self {
        PressureEwma(AtomicU64::new(0))
    }

    /// Fold one queue-wait sample into the EMA (α = 1/8).
    pub fn observe(&self, us: u64) {
        let old = self.0.load(Ordering::Relaxed);
        let new = if old == 0 { us } else { old - old / 8 + us / 8 };
        self.0.store(new, Ordering::Relaxed);
    }

    /// Decay toward zero so an idle pool scales back down.
    pub fn decay(&self) {
        let old = self.0.load(Ordering::Relaxed);
        self.0.store(old - old / 4, Ordering::Relaxed);
    }

    pub fn us(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for PressureEwma {
    fn default() -> Self {
        PressureEwma::new()
    }
}

struct VariantBreaker {
    core: Mutex<BreakerCore>,
    state_gauge: obs::Gauge,
}

/// Shared runtime state for the resilience layer: per-variant breakers
/// plus per-shard×variant queue-wait pressure. One instance per server,
/// shared by the submit path, the batchers, and the autoscale
/// controllers.
pub(crate) struct ResilienceRuntime {
    pub cfg: ResilienceConfig,
    breakers: BTreeMap<String, VariantBreaker>,
    /// variant → one EMA per shard.
    pressure: BTreeMap<String, Vec<PressureEwma>>,
    opened: obs::Counter,
    reclosed: obs::Counter,
    probing: obs::Counter,
}

impl ResilienceRuntime {
    pub fn new(cfg: ResilienceConfig, variants: &[String], shards: usize) -> Self {
        let now = Instant::now();
        let mut breakers = BTreeMap::new();
        if let Some(policy) = cfg.breaker {
            for v in variants {
                let state_gauge = obs::gauge(&format!("serve.breaker.{v}.state"));
                state_gauge.set(0);
                breakers.insert(
                    v.clone(),
                    VariantBreaker {
                        core: Mutex::new(BreakerCore::new(policy, now)),
                        state_gauge,
                    },
                );
            }
        }
        let pressure = variants
            .iter()
            .map(|v| {
                (
                    v.clone(),
                    (0..shards.max(1)).map(|_| PressureEwma::new()).collect(),
                )
            })
            .collect();
        ResilienceRuntime {
            cfg,
            breakers,
            pressure,
            opened: obs::counter("serve.breaker.opened"),
            reclosed: obs::counter("serve.breaker.reclosed"),
            probing: obs::counter("serve.breaker.probes"),
        }
    }

    /// Breaker admission check (true when no breaker is configured).
    pub fn allow(&self, variant: &str) -> bool {
        let Some(b) = self.breakers.get(variant) else {
            return true;
        };
        let mut core = b.core.lock().unwrap();
        let (ok, transition) = core.allow(Instant::now());
        if let Some(state) = transition {
            self.publish_transition(variant, b, state);
        }
        ok
    }

    /// Is this variant's queue-wait pressure above the degradation
    /// threshold on any shard?
    pub fn overloaded(&self, variant: &str) -> bool {
        let Some(limit) = self.cfg.degrade_queue_wait else {
            return false;
        };
        let limit_us = limit.as_micros() as u64;
        self.pressure
            .get(variant)
            .map(|per_shard| per_shard.iter().any(|p| p.us() > limit_us))
            .unwrap_or(false)
    }

    /// Degradation-ladder availability: breaker closed (or probing) and
    /// pressure under the threshold.
    pub fn routable(&self, variant: &str) -> bool {
        self.allow(variant) && !self.overloaded(variant)
    }

    /// Feed `n` execution outcomes for `variant` back into its breaker.
    pub fn on_batch_outcome(&self, variant: &str, ok: bool, n: usize) {
        let Some(b) = self.breakers.get(variant) else {
            return;
        };
        let mut core = b.core.lock().unwrap();
        for _ in 0..n {
            if let Some(state) = core.on_result(ok, Instant::now()) {
                self.publish_transition(variant, b, state);
            }
        }
    }

    fn publish_transition(&self, variant: &str, b: &VariantBreaker, state: BreakerState) {
        b.state_gauge.set(state.gauge());
        let fields = [("variant", variant.to_string())];
        match state {
            BreakerState::Open => {
                self.opened.inc();
                obs::warn("serve", "circuit breaker opened", &fields);
            }
            BreakerState::HalfOpen => {
                self.probing.inc();
                obs::info("serve", "circuit breaker probing (half-open)", &fields);
            }
            BreakerState::Closed => {
                self.reclosed.inc();
                obs::info("serve", "circuit breaker re-closed", &fields);
            }
        }
    }

    pub fn note_queue_wait(&self, shard: usize, variant: &str, us: u64) {
        if let Some(p) = self.pressure.get(variant).and_then(|v| v.get(shard)) {
            p.observe(us);
        }
    }

    pub fn queue_wait_us(&self, shard: usize, variant: &str) -> u64 {
        self.pressure
            .get(variant)
            .and_then(|v| v.get(shard))
            .map(|p| p.us())
            .unwrap_or(0)
    }

    pub fn decay_pressure(&self, shard: usize, variant: &str) {
        if let Some(p) = self.pressure.get(variant).and_then(|v| v.get(shard)) {
            p.decay();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BreakerPolicy {
        BreakerPolicy {
            window: 8,
            min_samples: 4,
            failure_ratio: 0.5,
            cooldown: Duration::from_millis(100),
            probes: 2,
        }
    }

    #[test]
    fn breaker_trips_after_failure_ratio_over_min_samples() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        assert_eq!(b.state(), BreakerState::Closed);
        // Three failures: below min_samples, still closed.
        for _ in 0..3 {
            assert_eq!(b.on_result(false, t0), None);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t0).0);
        // Fourth failure reaches min_samples at 100% failure rate.
        assert_eq!(b.on_result(false, t0), Some(BreakerState::Open));
        assert!(!b.allow(t0).0);
    }

    #[test]
    fn breaker_stays_closed_under_half_failure_window() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        // Alternate ok/fail: ratio sits at 0.5 boundary only on the
        // fail edges; feed mostly-ok traffic and it must never trip.
        for i in 0..64 {
            let ok = i % 3 != 0; // 1/3 failures < 0.5 ratio
            assert_eq!(b.on_result(ok, t0), None, "tripped at sample {i}");
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_probes_back_to_closed_after_cooldown() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        for _ in 0..4 {
            b.on_result(false, t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        // Before cooldown: blocked.
        let (ok, tr) = b.allow(t0 + Duration::from_millis(50));
        assert!(!ok && tr.is_none());
        // After cooldown: half-open, first probe admitted.
        let t1 = t0 + Duration::from_millis(150);
        let (ok, tr) = b.allow(t1);
        assert!(ok);
        assert_eq!(tr, Some(BreakerState::HalfOpen));
        // Second probe admitted, third blocked (probes = 2).
        assert!(b.allow(t1).0);
        assert!(!b.allow(t1).0);
        // Both probes succeed → re-closed.
        assert_eq!(b.on_result(true, t1), None);
        assert_eq!(b.on_result(true, t1), Some(BreakerState::Closed));
        assert!(b.allow(t1).0);
    }

    #[test]
    fn breaker_reopens_when_probe_fails() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        for _ in 0..4 {
            b.on_result(false, t0);
        }
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.allow(t1).0);
        assert_eq!(b.on_result(false, t1), Some(BreakerState::Open));
        // Cooldown restarts from the re-open instant.
        assert!(!b.allow(t1 + Duration::from_millis(50)).0);
        assert!(b.allow(t1 + Duration::from_millis(150)).0);
    }

    #[test]
    fn breaker_window_slides_old_failures_out() {
        let t0 = Instant::now();
        let mut b = BreakerCore::new(policy(), t0);
        // 3 failures then a long run of successes: the failures age out
        // of the window and the ratio can no longer trip.
        for _ in 0..3 {
            b.on_result(false, t0);
        }
        for _ in 0..8 {
            assert_eq!(b.on_result(true, t0), None);
        }
        // One more failure: window is now 7 ok + 1 fail — stays closed.
        assert_eq!(b.on_result(false, t0), None);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn restart_budget_grants_then_exhausts() {
        let t0 = Instant::now();
        let mut rb = RestartBudget::new(2, Duration::from_millis(10));
        assert_eq!(rb.request(t0), Some(Duration::ZERO));
        // Immediate second request is rate-limited to the interval.
        let wait = rb.request(t0).expect("second respawn within budget");
        assert_eq!(wait, Duration::from_millis(10));
        // Third request: exhausted.
        assert_eq!(rb.request(t0), None);
        assert_eq!(rb.used(), 2);
    }

    #[test]
    fn restart_budget_zero_always_escalates() {
        let mut rb = RestartBudget::new(0, Duration::ZERO);
        assert_eq!(rb.request(Instant::now()), None);
    }

    #[test]
    fn restart_budget_spaced_requests_wait_nothing() {
        let t0 = Instant::now();
        let mut rb = RestartBudget::new(3, Duration::from_millis(10));
        assert_eq!(rb.request(t0), Some(Duration::ZERO));
        assert_eq!(
            rb.request(t0 + Duration::from_millis(20)),
            Some(Duration::ZERO)
        );
    }

    #[test]
    fn autoscale_decision_grows_shrinks_and_holds() {
        let p = AutoscalePolicy {
            max_workers: 3,
            scale_up_wait: Duration::from_millis(2),
            scale_down_wait: Duration::from_micros(200),
            tick: Duration::from_millis(10),
        };
        // Pressure above the high watermark grows, up to the cap.
        assert_eq!(autoscale_decision(&p, 1, Duration::from_millis(5)), Some(2));
        assert_eq!(autoscale_decision(&p, 3, Duration::from_millis(5)), None);
        // Idle pool shrinks, but never below one worker.
        assert_eq!(
            autoscale_decision(&p, 2, Duration::from_micros(100)),
            Some(1)
        );
        assert_eq!(autoscale_decision(&p, 1, Duration::from_micros(100)), None);
        // In the hysteresis band: hold.
        assert_eq!(autoscale_decision(&p, 2, Duration::from_millis(1)), None);
    }

    #[test]
    fn pressure_ewma_tracks_and_decays() {
        let p = PressureEwma::new();
        assert_eq!(p.us(), 0);
        p.observe(8000);
        assert_eq!(p.us(), 8000);
        p.observe(8000);
        assert_eq!(p.us(), 8000);
        p.observe(0);
        assert!(p.us() < 8000);
        let before = p.us();
        p.decay();
        assert!(p.us() < before);
    }

    #[test]
    fn default_config_disables_every_feature() {
        let cfg = ResilienceConfig::default();
        assert_eq!(cfg.retries, 0);
        assert!(cfg.hedge_slack.is_none());
        assert!(cfg.breaker.is_none());
        assert_eq!(cfg.respawn_budget, 0);
        assert!(cfg.autoscale.is_none());
        assert!(cfg.degrade_queue_wait.is_none());
    }
}
