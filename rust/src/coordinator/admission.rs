//! Admission control: bounded per-variant queues with load shedding.
//!
//! The batcher channels are unbounded; without admission control a burst
//! can grow queue latency without bound (visible in the e2e example's
//! burst p50). The [`AdmissionController`] tracks in-flight requests per
//! variant and sheds load beyond a depth limit — the standard router-side
//! backpressure of serving systems (vLLM router-style).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Decision for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    /// Queue depth limit reached — caller should retry later or divert.
    Shed { depth: usize, limit: usize },
}

/// Shared admission state. `Ticket`s decrement the depth on drop, so a
/// completed (or abandoned) request always releases its slot.
#[derive(Debug)]
pub struct AdmissionController {
    limit: usize,
    depths: BTreeMap<String, Arc<AtomicUsize>>,
    shed_count: AtomicUsize,
    /// Registry mirrors: the process-wide in-flight gauge (all variants
    /// summed; RAII-decremented by tickets) and admitted/shed counters.
    in_flight: crate::obs::Gauge,
    admitted: crate::obs::Counter,
    shed: crate::obs::Counter,
}

/// RAII slot held while a request is in flight.
#[derive(Debug)]
pub struct Ticket {
    depth: Arc<AtomicUsize>,
    in_flight: crate::obs::Gauge,
}

impl Drop for Ticket {
    fn drop(&mut self) {
        self.depth.fetch_sub(1, Ordering::AcqRel);
        self.in_flight.add(-1);
    }
}

impl AdmissionController {
    pub fn new(limit: usize, variants: impl IntoIterator<Item = String>) -> Self {
        Self {
            limit: limit.max(1),
            depths: variants
                .into_iter()
                .map(|v| (v, Arc::new(AtomicUsize::new(0))))
                .collect(),
            shed_count: AtomicUsize::new(0),
            in_flight: crate::obs::gauge("serve.in_flight"),
            admitted: crate::obs::counter("serve.requests_admitted"),
            shed: crate::obs::counter("serve.requests_shed"),
        }
    }

    /// Try to admit one request for a variant.
    pub fn admit(&self, variant: &str) -> Option<Result<Ticket, Admission>> {
        let depth = self.depths.get(variant)?;
        // Optimistic increment with rollback keeps this lock-free.
        let prev = depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.limit {
            depth.fetch_sub(1, Ordering::AcqRel);
            self.shed_count.fetch_add(1, Ordering::Relaxed);
            self.shed.inc();
            return Some(Err(Admission::Shed {
                depth: prev,
                limit: self.limit,
            }));
        }
        self.admitted.inc();
        self.in_flight.add(1);
        Some(Ok(Ticket {
            depth: Arc::clone(depth),
            in_flight: self.in_flight.clone(),
        }))
    }

    /// Record a shed applied *past* admission — the sharded server's
    /// bounded ingress can refuse (`try_send` Full) a request admission
    /// already ticketed; counting it here keeps `shed_total` equal to
    /// every shed the server applied, wherever it happened.
    pub fn note_shed(&self) {
        self.shed_count.fetch_add(1, Ordering::Relaxed);
        self.shed.inc();
    }

    pub fn depth(&self, variant: &str) -> usize {
        self.depths
            .get(variant)
            .map(|d| d.load(Ordering::Acquire))
            .unwrap_or(0)
    }

    pub fn shed_total(&self) -> usize {
        self.shed_count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(limit: usize) -> AdmissionController {
        AdmissionController::new(limit, ["a".to_string(), "b".to_string()])
    }

    #[test]
    fn admits_until_limit_then_sheds() {
        let c = ctl(2);
        let t1 = c.admit("a").unwrap().unwrap();
        let t2 = c.admit("a").unwrap().unwrap();
        match c.admit("a").unwrap() {
            Err(Admission::Shed { depth: 2, limit: 2 }) => {}
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(c.shed_total(), 1);
        // Other variants are independent.
        let _t3 = c.admit("b").unwrap().unwrap();
        drop(t1);
        drop(t2);
        assert_eq!(c.depth("a"), 0);
        assert!(c.admit("a").unwrap().is_ok());
    }

    #[test]
    fn unknown_variant_is_none() {
        let c = ctl(1);
        assert!(c.admit("nope").is_none());
    }

    #[test]
    fn tickets_release_on_drop_even_in_panic_paths() {
        let c = ctl(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _t = c.admit("a").unwrap().unwrap();
            panic!("boom");
        }));
        assert!(r.is_err());
        assert_eq!(c.depth("a"), 0, "ticket must release through unwinding");
    }

    #[test]
    fn concurrent_admission_never_exceeds_limit() {
        let c = Arc::new(ctl(8));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            let max_seen = Arc::clone(&max_seen);
            handles.push(std::thread::spawn(move || {
                for _ in 0..2000 {
                    if let Some(Ok(_t)) = c.admit("a") {
                        let d = c.depth("a");
                        max_seen.fetch_max(d, Ordering::Relaxed);
                        // ticket drops immediately
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(max_seen.load(Ordering::Relaxed) <= 8);
        assert_eq!(c.depth("a"), 0);
    }
}
