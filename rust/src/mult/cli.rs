//! `openacm luts` — emit the behavioral-multiplier LUTs as `.npy` files.
//!
//! These are the same tables `python/compile/mults.py` generates on the
//! build path; emitting them from Rust lets the cross-language equivalence
//! test (`rust/tests/cross_language.rs`) and any downstream tooling compare
//! the two implementations bit for bit.

use anyhow::{Context, Result};
use std::path::Path;

use super::behavioral::{int8_lut, lut_to_npy, paper_families};
use crate::util::cli::Args;
use crate::util::npy;

/// Write `lut_<family>.npy` (int8 sign-magnitude product tables) for the
/// four paper families into `--out` (default `artifacts/luts-rust`).
pub fn cmd_luts(args: &Args) -> Result<()> {
    let out = args.str_or("out", "artifacts/luts-rust");
    let dir = Path::new(out);
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    for (name, family) in paper_families() {
        let lut = int8_lut(&family);
        let arr = lut_to_npy(&lut);
        let path = dir.join(format!("lut_{name}.npy"));
        npy::write(&path, &arr)?;
        println!("wrote {} ({} entries)", path.display(), lut.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn luts_roundtrip_through_files() {
        let tmp = std::env::temp_dir().join(format!("openacm_luts_{}", std::process::id()));
        let args = Args::parse(
            vec![format!("--out={}", tmp.display())],
            false,
            &[],
        )
        .unwrap();
        cmd_luts(&args).unwrap();
        let (shape, data) = npy::read_i32(&tmp.join("lut_exact.npy")).unwrap();
        assert_eq!(shape, vec![256, 256]);
        // exact LUT spot-check: 3 * 5
        let idx = ((3u8 as usize) << 8) | 5usize;
        assert_eq!(data[idx], 15);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
