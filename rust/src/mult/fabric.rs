//! The fabric abstraction: one circuit generator, two instantiations.
//!
//! A [`Fabric`] provides boolean primitives over an abstract bit type.
//! Multiplier generators written against it produce
//!
//! * a **gate netlist** when run on [`crate::gates::Builder`] (bit = net id);
//! * a **64-lane bit-parallel evaluation** when run on [`SoftFabric`]
//!   (bit = `u64`, one sample per lane).
//!
//! This guarantees the PPA/flow view and the application-level behavioral
//! view of an approximate multiplier are *the same circuit* by construction;
//! independent oracles (`a*b` for exact families, integer models for the
//! log families) then validate the construction itself.

use crate::gates::{Builder, NetId};

/// Boolean circuit fabric.
pub trait Fabric {
    type Bit: Copy;

    fn zero(&mut self) -> Self::Bit;
    fn one(&mut self) -> Self::Bit;
    fn not(&mut self, a: Self::Bit) -> Self::Bit;
    fn and(&mut self, a: Self::Bit, b: Self::Bit) -> Self::Bit;
    fn or(&mut self, a: Self::Bit, b: Self::Bit) -> Self::Bit;
    fn xor(&mut self, a: Self::Bit, b: Self::Bit) -> Self::Bit;

    /// sel ? b : a
    fn mux(&mut self, sel: Self::Bit, a: Self::Bit, b: Self::Bit) -> Self::Bit {
        let ns = self.not(sel);
        let l = self.and(ns, a);
        let r = self.and(sel, b);
        self.or(l, r)
    }

    fn xor3(&mut self, a: Self::Bit, b: Self::Bit, c: Self::Bit) -> Self::Bit {
        let t = self.xor(a, b);
        self.xor(t, c)
    }

    /// Majority-of-three (full-adder carry).
    fn maj(&mut self, a: Self::Bit, b: Self::Bit, c: Self::Bit) -> Self::Bit {
        let ab = self.and(a, b);
        let axb = self.xor(a, b);
        let t = self.and(axb, c);
        self.or(ab, t)
    }

    /// Half adder → (sum, carry).
    fn half_adder(&mut self, a: Self::Bit, b: Self::Bit) -> (Self::Bit, Self::Bit) {
        (self.xor(a, b), self.and(a, b))
    }

    /// Full adder → (sum, carry).
    fn full_adder(
        &mut self,
        a: Self::Bit,
        b: Self::Bit,
        c: Self::Bit,
    ) -> (Self::Bit, Self::Bit) {
        (self.xor3(a, b, c), self.maj(a, b, c))
    }
}

impl Fabric for Builder {
    type Bit = NetId;

    fn zero(&mut self) -> NetId {
        Builder::zero(self)
    }

    fn one(&mut self) -> NetId {
        Builder::one(self)
    }

    fn not(&mut self, a: NetId) -> NetId {
        Builder::not(self, a)
    }

    fn and(&mut self, a: NetId, b: NetId) -> NetId {
        Builder::and(self, a, b)
    }

    fn or(&mut self, a: NetId, b: NetId) -> NetId {
        Builder::or(self, a, b)
    }

    fn xor(&mut self, a: NetId, b: NetId) -> NetId {
        Builder::xor(self, a, b)
    }

    fn mux(&mut self, sel: NetId, a: NetId, b: NetId) -> NetId {
        Builder::mux(self, sel, a, b)
    }
}

/// 64-lane bit-parallel software fabric: each `u64` carries 64 independent
/// evaluation samples. Stateless.
#[derive(Clone, Copy, Debug, Default)]
pub struct SoftFabric;

impl Fabric for SoftFabric {
    type Bit = u64;

    #[inline]
    fn zero(&mut self) -> u64 {
        0
    }

    #[inline]
    fn one(&mut self) -> u64 {
        u64::MAX
    }

    #[inline]
    fn not(&mut self, a: u64) -> u64 {
        !a
    }

    #[inline]
    fn and(&mut self, a: u64, b: u64) -> u64 {
        a & b
    }

    #[inline]
    fn or(&mut self, a: u64, b: u64) -> u64 {
        a | b
    }

    #[inline]
    fn xor(&mut self, a: u64, b: u64) -> u64 {
        a ^ b
    }

    #[inline]
    fn mux(&mut self, sel: u64, a: u64, b: u64) -> u64 {
        (a & !sel) | (b & sel)
    }
}

/// Spread a single scalar's bits into full-lane constants (all 64 lanes get
/// the same sample). Used for one-off behavioral evaluation.
pub fn broadcast_bits(value: u64, bits: usize) -> Vec<u64> {
    (0..bits)
        .map(|i| if (value >> i) & 1 == 1 { u64::MAX } else { 0 })
        .collect()
}

/// Pack 64 scalar samples into lane-sliced form: `out[bit][lane]`.
/// `values.len() <= 64`; missing lanes are zero.
pub fn pack_lanes(values: &[u64], bits: usize) -> Vec<u64> {
    assert!(values.len() <= 64);
    let mut out = vec![0u64; bits];
    for (lane, &v) in values.iter().enumerate() {
        for (bit, slot) in out.iter_mut().enumerate() {
            if (v >> bit) & 1 == 1 {
                *slot |= 1u64 << lane;
            }
        }
    }
    out
}

/// Inverse of [`pack_lanes`]: collect `lanes` scalars from lane-sliced bits.
pub fn unpack_lanes(bits: &[u64], lanes: usize) -> Vec<u64> {
    assert!(lanes <= 64);
    (0..lanes)
        .map(|lane| {
            bits.iter()
                .enumerate()
                .fold(0u64, |acc, (bit, &word)| {
                    acc | (((word >> lane) & 1) << bit)
                })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_full_adder_matches_arithmetic() {
        let mut f = SoftFabric;
        for a in 0..2u64 {
            for b in 0..2u64 {
                for c in 0..2u64 {
                    let (s, carry) = f.full_adder(
                        if a == 1 { u64::MAX } else { 0 },
                        if b == 1 { u64::MAX } else { 0 },
                        if c == 1 { u64::MAX } else { 0 },
                    );
                    assert_eq!((s & 1) + 2 * (carry & 1), a + b + c);
                }
            }
        }
    }

    #[test]
    fn default_mux_matches_override() {
        let mut f = SoftFabric;
        for sel in [0u64, u64::MAX] {
            for a in [0u64, u64::MAX, 0x0F0F] {
                for b in [0u64, u64::MAX, 0xF0F0] {
                    assert_eq!(f.mux(sel, a, b), (a & !sel) | (b & sel));
                }
            }
        }
    }

    #[test]
    fn lane_pack_roundtrip() {
        let vals: Vec<u64> = (0..64).map(|i| (i * 37) & 0xFF).collect();
        let packed = pack_lanes(&vals, 8);
        let back = unpack_lanes(&packed, 64);
        assert_eq!(back, vals);
    }

    #[test]
    fn broadcast_all_lanes_agree() {
        let bits = broadcast_bits(0b1011, 4);
        assert_eq!(bits, vec![u64::MAX, u64::MAX, 0, u64::MAX]);
    }
}
