//! Logarithmic multipliers (paper §III-C, Fig 3).
//!
//! Mitchell's algorithm writes an operand as `N = 2^k (1 + x)`; the product
//! of two operands decomposes (Eq. 1) into
//!
//! ```text
//! A·B = 2^(k1+k2) + Q1·2^k2 + Q2·2^k1   (AP, shift-and-add only)
//!     +  Q1·Q2                           (EP, dropped by Mitchell [24])
//! ```
//!
//! with `Q1 = A − 2^k1`, `Q2 = B − 2^k2`. The paper's **Log-our** design
//! adds an *adder-free dynamic compensation* of the EP: the larger of
//! Q1/Q2 is rounded to its nearest power of two (over- or under-estimated,
//! Eq. 2), so `round(Q_big)·Q_small` is a pure shift; since this
//! compensation is provably `< 2^(k1+k2)`, it merges with the leading
//! `2^(k1+k2)` term through a bitwise **OR** instead of an adder (Eq. 3):
//!
//! ```text
//! P ≈ ( 2^(k1+k2) | round(Q_big)·Q_small ) + Q1·2^k2 + Q2·2^k1
//! ```
//!
//! Both multipliers are generated as netlists (LoDs, priority encoders,
//! XOR leading-one removal, barrel shifters, a comparator and the OR-merge)
//! and as independent integer behavioral models; equivalence is tested
//! exhaustively at 8 bits and by property tests at 16 bits.

use crate::gates::{Builder, NetId, Netlist};

// ---- behavioral models --------------------------------------------------

#[inline]
fn msb_pos(x: u64) -> u32 {
    63 - x.leading_zeros()
}

/// Mitchell LM [24]: AP only (EP dropped). `bits`-bit unsigned operands.
pub fn mitchell_behavioral(bits: usize, a: u64, b: u64) -> u64 {
    debug_assert!(a < (1 << bits) && b < (1 << bits));
    if a == 0 || b == 0 {
        return 0;
    }
    let k1 = msb_pos(a);
    let k2 = msb_pos(b);
    let q1 = a - (1 << k1);
    let q2 = b - (1 << k2);
    (1u64 << (k1 + k2)) + (q1 << k2) + (q2 << k1)
}

/// Round a positive value to its nearest power of two: `2^m` with
/// `m = msb` if the bit below the MSB is clear, else `2^(msb+1)`
/// (over-estimate when the residue is ≥ 1.5·2^msb). Returns the exponent.
#[inline]
fn round_pow2_exp(x: u64) -> u32 {
    debug_assert!(x > 0);
    let k = msb_pos(x);
    let roundup = k > 0 && (x >> (k - 1)) & 1 == 1;
    k + roundup as u32
}

/// The proposed Log-our multiplier (Eq. 3).
pub fn logour_behavioral(bits: usize, a: u64, b: u64) -> u64 {
    debug_assert!(a < (1 << bits) && b < (1 << bits));
    if a == 0 || b == 0 {
        return 0;
    }
    let k1 = msb_pos(a);
    let k2 = msb_pos(b);
    let q1 = a - (1 << k1);
    let q2 = b - (1 << k2);
    // Dynamic selection: round the LARGER residue (minimises WCE, §III-C),
    // shift the smaller one by the rounded exponent.
    let (big, small) = if q1 >= q2 { (q1, q2) } else { (q2, q1) };
    let comp = if big == 0 {
        0 // both residues zero (exact powers of two) → EP = 0
    } else {
        small << round_pow2_exp(big)
    };
    // comp < 2^(k1+k2): round(big) <= 2^k_big+1 <= 2^k1 (or 2^k2), and
    // small < 2^k_other, so the OR below never collides with bit k1+k2.
    debug_assert!(comp < (1u64 << (k1 + k2)));
    ((1u64 << (k1 + k2)) | comp) + (q1 << k2) + (q2 << k1)
}

// ---- netlists -----------------------------------------------------------

struct LogFrontEnd {
    /// Leading-one one-hot of the operand (kept for Verilog debug naming).
    _lod: Vec<NetId>,
    /// Binary exponent k (ceil(log2 bits) wide).
    k: Vec<NetId>,
    /// Residue Q = operand with its leading one cleared.
    q: Vec<NetId>,
    /// Operand-is-zero flag.
    is_zero: NetId,
}

/// LoD + priority encoder + XOR leading-one removal (Fig 3 AP front end).
fn log_front_end(b: &mut Builder, x: &[NetId]) -> LogFrontEnd {
    let lod = b.leading_one_detector(x);
    let k = b.onehot_encode(&lod);
    let q = b.xor_bus(x, &lod);
    let any = b.or_reduce(x);
    let is_zero = b.not(any);
    LogFrontEnd {
        _lod: lod,
        k,
        q,
        is_zero,
    }
}

/// Shared AP datapath: returns (`term1` = decoded 2^(k1+k2) bus of width 2n,
/// `s2` = Q1·2^k2 + Q2·2^k1 bus of width 2n, front-ends).
fn ap_datapath(
    b: &mut Builder,
    bits: usize,
    a_bus: &[NetId],
    b_bus: &[NetId],
) -> (Vec<NetId>, Vec<NetId>, LogFrontEnd, LogFrontEnd) {
    let width = 2 * bits;
    let fa = log_front_end(b, a_bus);
    let fb = log_front_end(b, b_bus);
    // Adder1: ksum = k1 + k2 (kbits+1 wide).
    let ksum = b.add_extend(&fa.k, &fb.k);
    // Decode ksum → one-hot 2^(k1+k2). ksum <= 2(bits-1) < 2*bits = width,
    // and the decoder emits 2^(kbits+1) >= width lines; truncate.
    let dec = b.decoder(&ksum);
    let term1: Vec<NetId> = dec.into_iter().take(width).collect();
    // Barrel shifts: Q1 << k2, Q2 << k1 (width 2n).
    let q1s = b.barrel_shl(&fa.q, &fb.k, width);
    let q2s = b.barrel_shl(&fb.q, &fa.k, width);
    // Adder2 (carry-select above 12 bits to stay inside the SRAM clock).
    let s2 = crate::mult::pptree::cpa_gen(b, &q1s, &q2s);
    (term1, s2, fa, fb)
}

/// Gate the final product with NOT(a==0 OR b==0).
fn gate_zero(b: &mut Builder, fa_zero: NetId, fb_zero: NetId, p: &[NetId]) -> Vec<NetId> {
    let any_zero = b.or(fa_zero, fb_zero);
    let live = b.not(any_zero);
    b.gate_bus(live, p)
}

/// Mitchell LM netlist.
pub fn build_mitchell(bits: usize) -> Netlist {
    let mut b = Builder::new(&format!("mult_mitchell_{bits}b"));
    let a_bus = b.input_bus("a", bits);
    let b_bus = b.input_bus("b", bits);
    let (term1, s2, fa, fb) = ap_datapath(&mut b, bits, &a_bus, &b_bus);
    let p = crate::mult::pptree::cpa_gen(&mut b, &term1, &s2);
    let p = gate_zero(&mut b, fa.is_zero, fb.is_zero, &p);
    b.output_bus("p", &p);
    let nl = b.finish();
    nl.validate().expect("mitchell netlist must validate");
    nl
}

/// Log-our netlist (Fig 3): AP datapath + EP compensation processing
/// element (COMP, rounding, barrel shift) + OR-merge + Adder3.
pub fn build_logour(bits: usize) -> Netlist {
    let mut b = Builder::new(&format!("mult_logour_{bits}b"));
    let a_bus = b.input_bus("a", bits);
    let b_bus = b.input_bus("b", bits);
    let width = 2 * bits;
    let (term1, s2, fa, fb) = ap_datapath(&mut b, bits, &a_bus, &b_bus);

    // --- EP processing element ---
    // COMP: pick the larger residue.
    let (q1_gt, _eq) = b.compare(&fa.q, &fb.q);
    // big = q1_gt ? q1 : q2  (ties → q2, matches behavioral q1 >= q2 when
    // equal only if values equal — identical results either way).
    let big = b.mux_bus(q1_gt, &fb.q, &fa.q);
    let small = b.mux_bus(q1_gt, &fa.q, &fb.q);
    // round(big): exponent = msb(big) + [bit below msb set].
    let lod_big = b.leading_one_detector(&big);
    let kb = b.onehot_encode(&lod_big);
    // roundup = OR over i>=1 of lod_big[i] & big[i-1]
    let mut ups = Vec::new();
    for i in 1..bits {
        let t = b.and(lod_big[i], big[i - 1]);
        ups.push(t);
    }
    let roundup = b.or_reduce(&ups);
    // e = kb + roundup (kb width + 1).
    let zero = b.zero();
    let mut roundup_bus = vec![zero; kb.len()];
    roundup_bus[0] = roundup;
    let e = b.add_extend(&kb, &roundup_bus);
    // comp = small << e (pure shift — the "adder-free" compensation).
    let comp = b.barrel_shl(&small, &e, width);
    // If big == 0 the EP is zero: comp must be forced to 0 (otherwise
    // small<<0 = small would leak; note small <= big so small == 0 too —
    // the gate keeps the netlist faithful to the spec regardless).
    let big_any = b.or_reduce(&big);
    let comp = b.gate_bus(big_any, &comp);

    // OR-merge with the decoded 2^(k1+k2) (no carry possible, §III-C).
    let merged = b.or_bus(&term1, &comp);
    // Adder3.
    let p = crate::mult::pptree::cpa_gen(&mut b, &merged, &s2);
    let p = gate_zero(&mut b, fa.is_zero, fb.is_zero, &p);
    b.output_bus("p", &p);
    let nl = b.finish();
    nl.validate().expect("logour netlist must validate");
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn eval(nl: &Netlist, a: u64, b: u64) -> u64 {
        let mut ops = BTreeMap::new();
        ops.insert("a".to_string(), a);
        ops.insert("b".to_string(), b);
        nl.eval_uint(&ops)["p"]
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn mitchell_netlist_matches_behavioral_exhaustive_8bit() {
        let nl = build_mitchell(8);
        for a in 0..256u64 {
            for b in 0..256u64 {
                assert_eq!(
                    eval(&nl, a, b),
                    mitchell_behavioral(8, a, b),
                    "mitchell {a}*{b}"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn logour_netlist_matches_behavioral_exhaustive_8bit() {
        let nl = build_logour(8);
        for a in 0..256u64 {
            for b in 0..256u64 {
                assert_eq!(
                    eval(&nl, a, b),
                    logour_behavioral(8, a, b),
                    "logour {a}*{b}"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn netlists_match_behavioral_16bit_sampled() {
        let lm = build_mitchell(16);
        let lo = build_logour(16);
        crate::util::proptest::check(300, 0x10b2, |g| {
            let a = g.u64_bits(16);
            let b = g.u64_bits(16);
            let m_ok = eval(&lm, a, b) == mitchell_behavioral(16, a, b);
            let l_ok = eval(&lo, a, b) == logour_behavioral(16, a, b);
            crate::util::proptest::prop_assert(m_ok && l_ok, format!("{a}*{b}"))
        });
    }

    #[test]
    fn exact_on_powers_of_two() {
        // Both log multipliers are exact when both operands are powers of 2.
        for i in 0..8 {
            for j in 0..8 {
                let a = 1u64 << i;
                let b = 1u64 << j;
                assert_eq!(mitchell_behavioral(8, a, b), a * b);
                assert_eq!(logour_behavioral(8, a, b), a * b);
            }
        }
    }

    #[test]
    fn zero_operands() {
        for x in [0u64, 1, 37, 255] {
            assert_eq!(mitchell_behavioral(8, 0, x), 0);
            assert_eq!(mitchell_behavioral(8, x, 0), 0);
            assert_eq!(logour_behavioral(8, 0, x), 0);
            assert_eq!(logour_behavioral(8, x, 0), 0);
        }
    }

    #[test]
    fn compensation_never_carries_into_leading_term() {
        // The OR-merge invariant (Eq. 3): comp < 2^(k1+k2), exhaustively.
        for a in 1..256u64 {
            for b in 1..256u64 {
                let k1 = 63 - a.leading_zeros();
                let k2 = 63 - b.leading_zeros();
                let q1 = a - (1 << k1);
                let q2 = b - (1 << k2);
                let (big, small) = if q1 >= q2 { (q1, q2) } else { (q2, q1) };
                if big == 0 {
                    continue;
                }
                let comp = small << super::round_pow2_exp(big);
                assert!(
                    comp < (1u64 << (k1 + k2)),
                    "a={a} b={b}: comp {comp} >= 2^{}",
                    k1 + k2
                );
            }
        }
    }

    #[test]
    fn logour_strictly_more_accurate_than_mitchell() {
        // Exhaustive 8-bit mean absolute error: the compensation must cut
        // the error substantially (the paper reports NMED 4.4e-3 vs 2.8e-2).
        let mut lm_err = 0f64;
        let mut lo_err = 0f64;
        for a in 0..256u64 {
            for b in 0..256u64 {
                let exact = (a * b) as i64;
                lm_err += (mitchell_behavioral(8, a, b) as i64 - exact).abs() as f64;
                lo_err += (logour_behavioral(8, a, b) as i64 - exact).abs() as f64;
            }
        }
        assert!(
            lo_err < 0.5 * lm_err,
            "logour abs error {lo_err} not well below mitchell {lm_err}"
        );
    }

    #[test]
    fn mitchell_error_is_one_sided_underestimate() {
        // Mitchell drops the (positive) EP, so it never overestimates.
        for a in 0..256u64 {
            for b in 0..256u64 {
                assert!(mitchell_behavioral(8, a, b) <= a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn round_pow2_nearest() {
        assert_eq!(round_pow2_exp(1), 0); // 1 → 2^0
        assert_eq!(round_pow2_exp(2), 1); // 2 → 2^1
        assert_eq!(round_pow2_exp(3), 2); // 3 → 2^2 (over-estimate, 3 ≥ 1.5·2)
        assert_eq!(round_pow2_exp(4), 2);
        assert_eq!(round_pow2_exp(5), 2); // 5 < 6 → keep 2^2
        assert_eq!(round_pow2_exp(6), 3); // 6 ≥ 6 → 2^3
        assert_eq!(round_pow2_exp(7), 3);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn logour_gate_count_grows_slower_than_exact() {
        // Table II: at 32 bits the log multiplier's logic is ~half the
        // exact compressor tree; at 8 bits it is allowed to be bigger.
        use super::super::pptree::build_exact;
        let lo32 = build_logour(32).logic_gate_count();
        let ex32 = build_exact(32).logic_gate_count();
        assert!(
            (lo32 as f64) < 0.8 * ex32 as f64,
            "32b: logour {lo32} vs exact {ex32}"
        );
    }
}
