//! Partial-product-tree multipliers (Fig 2): PP generation by AND gates,
//! a configurable reduction tree (exact or approximate 4-2 compressors on
//! selected low-order columns, full adders elsewhere), and a final
//! carry-propagate adder. Plus the OpenC²-style adder-tree baseline.
//!
//! Everything is generic over [`Fabric`], so the same generator yields the
//! gate netlist and the 64-lane software evaluator.

use super::compressor::{approx42, exact42};
use super::fabric::Fabric;
use crate::config::spec::CompressorKind;
use crate::gates::{Builder, Netlist};

/// Generate the AND-gate partial-product matrix: `cols[w]` holds all PP
/// bits of weight `2^w` (LSB-first operands).
pub fn pp_matrix<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<Vec<F::Bit>> {
    let n = a.len();
    let m = b.len();
    let mut cols: Vec<Vec<F::Bit>> = vec![Vec::new(); n + m];
    for (i, &ai) in a.iter().enumerate() {
        for (j, &bj) in b.iter().enumerate() {
            let pp = f.and(ai, bj);
            cols[i + j].push(pp);
        }
    }
    cols
}

/// One reduction pass statistics (used by tests and the PPA report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReduceStats {
    pub stages: usize,
    pub exact_compressors: usize,
    pub approx_compressors: usize,
    pub full_adders: usize,
    pub half_adders: usize,
}

/// Reduce the PP matrix to two rows with a compressor tree.
///
/// Columns with weight `< approx_cols` use the approximate design `kind`
/// (the Fig 2 red box: for the paper's 8-bit default, columns #0..#7);
/// all other columns use exact 4-2 compressors / full adders.
pub fn reduce_tree<F: Fabric>(
    f: &mut F,
    mut cols: Vec<Vec<F::Bit>>,
    approx_cols: usize,
    kind: Option<CompressorKind>,
    stats: &mut ReduceStats,
) -> (Vec<F::Bit>, Vec<F::Bit>) {
    let width = cols.len();
    while cols.iter().any(|c| c.len() > 2) {
        stats.stages += 1;
        let mut next: Vec<Vec<F::Bit>> = vec![Vec::new(); width + 1];
        for w in 0..width {
            let bits = std::mem::take(&mut cols[w]);
            let mut it = bits.into_iter().peekable();
            let mut pending: Vec<F::Bit> = Vec::new();
            while it.peek().is_some() {
                pending.push(it.next().unwrap());
                if pending.len() == 4 {
                    let (x1, x2, x3, x4) = (pending[0], pending[1], pending[2], pending[3]);
                    pending.clear();
                    let approx_here = kind.is_some() && w < approx_cols;
                    if approx_here {
                        let (s, c) = approx42(f, kind.unwrap(), x1, x2, x3, x4);
                        next[w].push(s);
                        next[w + 1].push(c);
                        stats.approx_compressors += 1;
                    } else {
                        let z = f.zero();
                        let (s, c, co) = exact42(f, x1, x2, x3, x4, z);
                        next[w].push(s);
                        next[w + 1].push(c);
                        next[w + 1].push(co);
                        stats.exact_compressors += 1;
                    }
                }
            }
            match pending.len() {
                3 => {
                    let (s, c) = f.full_adder(pending[0], pending[1], pending[2]);
                    next[w].push(s);
                    next[w + 1].push(c);
                    stats.full_adders += 1;
                }
                2 => {
                    // Pass through; a half adder here would not reduce the
                    // critical column count and only burns area (Dadda rule).
                    next[w].push(pending[0]);
                    next[w].push(pending[1]);
                }
                1 => next[w].push(pending[0]),
                0 => {}
                _ => unreachable!(),
            }
        }
        next.truncate(width); // weights >= 2^width overflow the product; drop
        cols = next;
    }
    let z = f.zero();
    let mut row1 = Vec::with_capacity(width);
    let mut row2 = Vec::with_capacity(width);
    for col in cols {
        row1.push(*col.first().unwrap_or(&z));
        row2.push(*col.get(1).unwrap_or(&z));
    }
    (row1, row2)
}

/// Generic ripple-carry addition (final CPA), truncated to the input width.
pub fn ripple_add_gen<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<F::Bit> {
    assert_eq!(a.len(), b.len());
    let mut carry = f.zero();
    let mut out = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (s, c) = f.full_adder(a[i], b[i], carry);
        out.push(s);
        carry = c;
    }
    out
}

/// Carry-select addition: blocks computed for both carry-in values in
/// parallel, selected by a short mux chain. Delay ≈ one block of ripple +
/// one mux per block instead of a full-width ripple — this is what keeps
/// the 16/32-bit multipliers' critical paths inside the SRAM-dominated
/// 5.2 ns clock (Table II). ~2× the adder area of plain ripple.
pub fn select_add_gen<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<F::Bit> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let block = 4usize.max(n / 8);
    let mut out = Vec::with_capacity(n);
    let mut carry = f.zero();
    let mut start = 0;
    while start < n {
        let end = (start + block).min(n);
        let mut c0 = f.zero();
        let mut c1 = f.one();
        let mut sum0 = Vec::with_capacity(end - start);
        let mut sum1 = Vec::with_capacity(end - start);
        for i in start..end {
            let (s, c) = f.full_adder(a[i], b[i], c0);
            sum0.push(s);
            c0 = c;
            let (s, c) = f.full_adder(a[i], b[i], c1);
            sum1.push(s);
            c1 = c;
        }
        for j in 0..sum0.len() {
            out.push(f.mux(carry, sum0[j], sum1[j]));
        }
        carry = f.mux(carry, c0, c1);
        start = end;
    }
    out
}

/// Kogge–Stone parallel-prefix addition: O(log n) depth, O(n log n) gates.
/// The fastest CPA in the library; used for wide final adders where the
/// ripple (or even carry-select) chain would blow the SRAM-dominated clock.
pub fn prefix_add_gen<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<F::Bit> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return vec![];
    }
    let p0: Vec<F::Bit> = (0..n).map(|i| f.xor(a[i], b[i])).collect();
    let mut g: Vec<F::Bit> = (0..n).map(|i| f.and(a[i], b[i])).collect();
    let mut p = p0.clone();
    let mut step = 1;
    while step < n {
        let mut g2 = g.clone();
        let mut p2 = p.clone();
        for i in (step..n).rev() {
            let t = f.and(p[i], g[i - step]);
            g2[i] = f.or(g[i], t);
            p2[i] = f.and(p[i], p[i - step]);
        }
        g = g2;
        p = p2;
        step *= 2;
    }
    // carry into bit i is G[i-1]; sum = p0 ^ carry_in.
    let mut out = Vec::with_capacity(n);
    out.push(p0[0]);
    for i in 1..n {
        out.push(f.xor(p0[i], g[i - 1]));
    }
    out
}

/// Final CPA selection: ripple for narrow words, parallel-prefix for wide.
pub fn cpa_gen<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<F::Bit> {
    if a.len() >= 12 {
        prefix_add_gen(f, a, b)
    } else {
        ripple_add_gen(f, a, b)
    }
}

/// Full generic PP-tree multiplier: returns the 2n product bits.
pub fn multiply_pptree<F: Fabric>(
    f: &mut F,
    a: &[F::Bit],
    b: &[F::Bit],
    approx_cols: usize,
    kind: Option<CompressorKind>,
    stats: &mut ReduceStats,
) -> Vec<F::Bit> {
    let cols = pp_matrix(f, a, b);
    let (r1, r2) = reduce_tree(f, cols, approx_cols, kind, stats);
    cpa_gen(f, &r1, &r2)
}

/// OpenC²-style baseline: PP rows summed by a binary adder tree built from
/// ripple adders (no compressors). More gates than the compressor tree.
pub fn multiply_adder_tree<F: Fabric>(f: &mut F, a: &[F::Bit], b: &[F::Bit]) -> Vec<F::Bit> {
    let n = a.len();
    let m = b.len();
    let width = n + m;
    let z = f.zero();
    // Row j = (a AND b[j]) << j, width 2n.
    let mut rows: Vec<Vec<F::Bit>> = (0..m)
        .map(|j| {
            let mut row = vec![z; width];
            for (i, &ai) in a.iter().enumerate() {
                row[i + j] = f.and(ai, b[j]);
            }
            row
        })
        .collect();
    // Binary tree of ripple adders.
    while rows.len() > 1 {
        let mut next = Vec::with_capacity(rows.len().div_ceil(2));
        let mut it = rows.into_iter();
        while let Some(r1) = it.next() {
            match it.next() {
                Some(r2) => next.push(cpa_gen(f, &r1, &r2)),
                None => next.push(r1),
            }
        }
        rows = next;
    }
    rows.pop().unwrap_or_else(|| vec![z; width])
}

// ---- netlist front-ends -----------------------------------------------

fn build_common(
    name: &str,
    bits: usize,
    gen: impl FnOnce(&mut Builder, &[crate::gates::NetId], &[crate::gates::NetId]) -> Vec<crate::gates::NetId>,
) -> Netlist {
    let mut b = Builder::new(name);
    let a_bus = b.input_bus("a", bits);
    let b_bus = b.input_bus("b", bits);
    let p = gen(&mut b, &a_bus, &b_bus);
    assert_eq!(p.len(), 2 * bits);
    b.output_bus("p", &p);
    let nl = b.finish();
    nl.validate().expect("generated netlist must validate");
    nl
}

/// Exact 4-2-compressor multiplier netlist.
pub fn build_exact(bits: usize) -> Netlist {
    build_common(&format!("mult_exact_{bits}b"), bits, |f, a, b| {
        let mut st = ReduceStats::default();
        multiply_pptree(f, a, b, 0, None, &mut st)
    })
}

/// Tunable approximate multiplier netlist (Fig 2).
pub fn build_approx42(bits: usize, kind: CompressorKind, approx_cols: usize) -> Netlist {
    build_common(
        &format!("mult_appro42_{}_{}c_{bits}b", kind.name(), approx_cols),
        bits,
        |f, a, b| {
            let mut st = ReduceStats::default();
            multiply_pptree(f, a, b, approx_cols, Some(kind), &mut st)
        },
    )
}

/// OpenC²-style adder-tree multiplier netlist (baseline).
pub fn build_adder_tree(bits: usize) -> Netlist {
    build_common(&format!("mult_addertree_{bits}b"), bits, |f, a, b| {
        multiply_adder_tree(f, a, b)
    })
}

/// Software multiply via the same generator (single sample).
pub fn soft_multiply(
    bits: usize,
    approx_cols: usize,
    kind: Option<CompressorKind>,
    a: u64,
    b: u64,
) -> u64 {
    use super::fabric::{broadcast_bits, SoftFabric};
    let mut f = SoftFabric;
    let av = broadcast_bits(a, bits);
    let bv = broadcast_bits(b, bits);
    let mut st = ReduceStats::default();
    let p = multiply_pptree(&mut f, &av, &bv, approx_cols, kind, &mut st);
    p.iter()
        .enumerate()
        .fold(0u64, |acc, (i, &w)| acc | ((w & 1) << i))
}

/// Software multiply, 64 (a, b) pairs at once (lane-sliced).
pub fn soft_multiply_lanes(
    bits: usize,
    approx_cols: usize,
    kind: Option<CompressorKind>,
    a_vals: &[u64],
    b_vals: &[u64],
) -> Vec<u64> {
    use super::fabric::{pack_lanes, unpack_lanes, SoftFabric};
    assert_eq!(a_vals.len(), b_vals.len());
    assert!(a_vals.len() <= 64);
    let mut f = SoftFabric;
    let av = pack_lanes(a_vals, bits);
    let bv = pack_lanes(b_vals, bits);
    let mut st = ReduceStats::default();
    let p = multiply_pptree(&mut f, &av, &bv, approx_cols, kind, &mut st);
    unpack_lanes(&p, a_vals.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn eval_netlist_mult(nl: &Netlist, a: u64, b: u64) -> u64 {
        let mut ops = BTreeMap::new();
        ops.insert("a".to_string(), a);
        ops.insert("b".to_string(), b);
        nl.eval_uint(&ops)["p"]
    }

    #[test]
    fn exact_multiplier_exhaustive_6bit() {
        let nl = build_exact(6);
        for a in 0..64u64 {
            for b in 0..64u64 {
                assert_eq!(eval_netlist_mult(&nl, a, b), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn exact_multiplier_8bit_spot_plus_lanes() {
        // Exhaustive via 64-lane software evaluation (fast), netlist spot.
        let nl = build_exact(8);
        for a in (0..256u64).step_by(17) {
            for b in (0..256u64).step_by(13) {
                assert_eq!(eval_netlist_mult(&nl, a, b), a * b);
            }
        }
        // lanes: all 65536 pairs
        let mut pairs_a = Vec::with_capacity(64);
        let mut pairs_b = Vec::with_capacity(64);
        for a in 0..256u64 {
            for b in 0..256u64 {
                pairs_a.push(a);
                pairs_b.push(b);
                if pairs_a.len() == 64 {
                    let prods = soft_multiply_lanes(8, 0, None, &pairs_a, &pairs_b);
                    for ((&x, &y), p) in pairs_a.iter().zip(&pairs_b).zip(prods) {
                        assert_eq!(p, x * y);
                    }
                    pairs_a.clear();
                    pairs_b.clear();
                }
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn adder_tree_is_exact() {
        let nl = build_adder_tree(6);
        for a in (0..64u64).step_by(3) {
            for b in 0..64u64 {
                assert_eq!(eval_netlist_mult(&nl, a, b), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn adder_tree_costs_more_gates_than_compressor_tree() {
        // The paper's Table II premise: OpenC² (adder tree) > Exact (4-2).
        for bits in [8, 16] {
            let at = build_adder_tree(bits).logic_gate_count();
            let ex = build_exact(bits).logic_gate_count();
            assert!(
                at > ex,
                "{bits}b: adder-tree {at} should exceed compressor-tree {ex}"
            );
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approx_netlist_matches_soft_fabric_exhaustive_8bit() {
        use crate::config::spec::CompressorKind;
        let kind = CompressorKind::Yang1;
        let nl = build_approx42(8, kind, 8);
        let mut pa = Vec::new();
        let mut pb = Vec::new();
        let mut expect = Vec::new();
        for a in (0..256u64).step_by(5) {
            for b in (0..256u64).step_by(7) {
                pa.push(a);
                pb.push(b);
                expect.push(eval_netlist_mult(&nl, a, b));
                if pa.len() == 64 {
                    let got = soft_multiply_lanes(8, 8, Some(kind), &pa, &pb);
                    assert_eq!(got, expect);
                    pa.clear();
                    pb.clear();
                    expect.clear();
                }
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approx_zero_cols_equals_exact() {
        use crate::config::spec::CompressorKind;
        // approx_cols = 0 must degrade to the exact multiplier.
        for a in (0..256u64).step_by(11) {
            for b in (0..256u64).step_by(19) {
                let p = soft_multiply(8, 0, Some(CompressorKind::Yang1), a, b);
                assert_eq!(p, a * b);
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approx_error_bounded_by_column_budget() {
        use crate::config::spec::CompressorKind;
        // With approximate compressors only on columns < 8, the error is
        // bounded by a small multiple of 2^8.
        let mut max_err = 0i64;
        for a in (0..256u64).step_by(3) {
            for b in (0..256u64).step_by(3) {
                let p = soft_multiply(8, 8, Some(CompressorKind::Yang1), a, b) as i64;
                let e = (p - (a * b) as i64).abs();
                max_err = max_err.max(e);
            }
        }
        assert!(max_err > 0, "approximation must actually approximate");
        assert!(
            max_err < 8 * 256,
            "error {max_err} exceeds the column budget"
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn more_approx_cols_means_more_error_fewer_gates() {
        use crate::config::spec::CompressorKind;
        let kind = CompressorKind::Yang1;
        let mut prev_gates = usize::MAX;
        let mut prev_err = -1f64;
        for cols in [0usize, 4, 8, 12] {
            let nl = build_approx42(8, kind, cols);
            let gates = nl.logic_gate_count();
            // mean |error| over a sample grid
            let mut err_sum = 0f64;
            let mut n = 0f64;
            for a in (0..256u64).step_by(7) {
                for b in (0..256u64).step_by(7) {
                    let p = soft_multiply(8, cols, Some(kind), a, b) as i64;
                    err_sum += ((p - (a * b) as i64).abs()) as f64;
                    n += 1.0;
                }
            }
            let err = err_sum / n;
            assert!(gates <= prev_gates, "gate count must not grow with cols");
            assert!(err >= prev_err, "error must not shrink with cols");
            prev_gates = gates;
            prev_err = err;
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn sixteen_bit_exact_sampled() {
        let nl = build_exact(16);
        crate::util::proptest::check(200, 0x16b1, |g| {
            let a = g.u64_bits(16);
            let b = g.u64_bits(16);
            let p = eval_netlist_mult(&nl, a, b);
            crate::util::proptest::prop_assert(p == a * b, format!("{a}*{b} got {p}"))
        });
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn thirtytwo_bit_exact_sampled() {
        let nl = build_exact(32);
        crate::util::proptest::check(50, 0x32b1, |g| {
            let a = g.u64_bits(32);
            let b = g.u64_bits(32);
            let p = eval_netlist_mult(&nl, a, b);
            crate::util::proptest::prop_assert(p == a * b, format!("{a}*{b} got {p}"))
        });
    }

    #[test]
    fn reduce_stats_populated() {
        let mut f = super::super::fabric::SoftFabric;
        let a = super::super::fabric::broadcast_bits(0xAB, 8);
        let b = super::super::fabric::broadcast_bits(0xCD, 8);
        let mut st = ReduceStats::default();
        let _ = multiply_pptree(&mut f, &a, &b, 8, Some(CompressorKind::Yang1), &mut st);
        assert!(st.stages >= 2);
        assert!(st.approx_compressors > 0);
        assert!(st.exact_compressors > 0); // upper columns stay exact
    }
}
