//! Error metrics for approximate multipliers (paper Tab. IV):
//!
//! * **NMED** — normalized mean error distance: `mean(|p̂ − p|) / p_max`;
//! * **MRED** — mean relative error distance: `mean(|p̂ − p| / p)` over
//!   nonzero exact products;
//! * **ER** — error rate, **WCE** — worst-case error, and the signed bias
//!   (which explains the paper's observation that Log-our's zero-mean
//!   errors behave like noise regularization while Appro4-2's one-sided
//!   errors accumulate).
//!
//! Exhaustive for widths ≤ 12 bits; seeded uniform sampling above.

use super::behavioral::behavioral_fn;
use crate::config::spec::MultFamily;
use crate::util::rng::Pcg32;

/// Full error report for one multiplier configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorReport {
    pub nmed: f64,
    pub mred: f64,
    pub error_rate: f64,
    pub wce: u64,
    /// Signed mean error / p_max — negative = systematic underestimate.
    pub normalized_bias: f64,
    /// Number of (a, b) pairs evaluated.
    pub samples: u64,
}

/// Compute metrics exhaustively over all `2^bits × 2^bits` input pairs.
pub fn exhaustive(family: &MultFamily, bits: usize) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits; use sampled()");
    let f = behavioral_fn(family, bits);
    let n = 1u64 << bits;
    let p_max = ((n - 1) * (n - 1)) as f64;
    let mut abs_sum = 0f64;
    let mut signed_sum = 0f64;
    let mut rel_sum = 0f64;
    let mut rel_n = 0u64;
    let mut wrong = 0u64;
    let mut wce = 0u64;
    for a in 0..n {
        for b in 0..n {
            let exact = (a * b) as i64;
            let got = f(a, b) as i64;
            let err = got - exact;
            if err != 0 {
                wrong += 1;
            }
            let ae = err.unsigned_abs();
            wce = wce.max(ae);
            abs_sum += ae as f64;
            signed_sum += err as f64;
            if exact != 0 {
                rel_sum += ae as f64 / exact as f64;
                rel_n += 1;
            }
        }
    }
    let total = (n * n) as f64;
    ErrorReport {
        nmed: abs_sum / total / p_max,
        mred: rel_sum / rel_n as f64,
        error_rate: wrong as f64 / total,
        wce,
        normalized_bias: signed_sum / total / p_max,
        samples: n * n,
    }
}

/// Sampled metrics for wide multipliers.
pub fn sampled(family: &MultFamily, bits: usize, samples: u64, seed: u64) -> ErrorReport {
    let f = behavioral_fn(family, bits);
    let mut rng = Pcg32::new(seed);
    let mask = (1u128 << bits) - 1;
    let p_max = (((1u128 << bits) - 1) * ((1u128 << bits) - 1)) as f64;
    let mut abs_sum = 0f64;
    let mut signed_sum = 0f64;
    let mut rel_sum = 0f64;
    let mut rel_n = 0u64;
    let mut wrong = 0u64;
    let mut wce = 0u64;
    for _ in 0..samples {
        let a = (rng.next_u64() as u128 & mask) as u64;
        let b = (rng.next_u64() as u128 & mask) as u64;
        let exact = (a as u128 * b as u128) as i128;
        let got = f(a, b) as i128;
        let err = got - exact;
        if err != 0 {
            wrong += 1;
        }
        let ae = err.unsigned_abs() as u64;
        wce = wce.max(ae);
        abs_sum += ae as f64;
        signed_sum += err as f64;
        if exact != 0 {
            rel_sum += ae as f64 / exact as f64;
            rel_n += 1;
        }
    }
    ErrorReport {
        nmed: abs_sum / samples as f64 / p_max,
        mred: rel_sum / rel_n.max(1) as f64,
        error_rate: wrong as f64 / samples as f64,
        wce,
        normalized_bias: signed_sum / samples as f64 / p_max,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::CompressorKind;

    #[test]
    fn exact_families_have_zero_error() {
        for fam in [MultFamily::Exact, MultFamily::AdderTree] {
            let r = exhaustive(&fam, 8);
            assert_eq!(r.nmed, 0.0);
            assert_eq!(r.mred, 0.0);
            assert_eq!(r.error_rate, 0.0);
            assert_eq!(r.wce, 0);
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn paper_table4_nmed_ordering() {
        // Tab. IV: NMED(Appro4-2) << NMED(Log-our) << NMED(LM[24]).
        let appro = exhaustive(&MultFamily::default_approx(8), 8);
        let logour = exhaustive(&MultFamily::LogOur, 8);
        let lm = exhaustive(&MultFamily::Mitchell, 8);
        assert!(
            appro.nmed < logour.nmed && logour.nmed < lm.nmed,
            "NMED ordering violated: appro={:.3e} logour={:.3e} lm={:.3e}",
            appro.nmed,
            logour.nmed,
            lm.nmed
        );
        // Paper magnitudes (8-bit native): logour ~4.4e-3, lm ~2.8e-2.
        assert!(logour.nmed < 2e-2, "logour nmed {:.3e}", logour.nmed);
        assert!(lm.nmed > 5e-3 && lm.nmed < 8e-2, "lm nmed {:.3e}", lm.nmed);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn appro42_bias_is_one_sided_logour_is_balanced() {
        // The paper's §V-B argument: yang1's errors are one-sided
        // (systematic) while Log-our's are near zero-mean.
        let appro = exhaustive(&MultFamily::default_approx(8), 8);
        let logour = exhaustive(&MultFamily::LogOur, 8);
        assert!(appro.normalized_bias < 0.0);
        assert!(
            appro.normalized_bias.abs() > 0.9 * appro.nmed,
            "appro4-2 errors should be almost fully one-sided"
        );
        assert!(
            logour.normalized_bias.abs() < 0.8 * logour.nmed,
            "log-our errors should partially cancel (bias {:.3e} vs nmed {:.3e})",
            logour.normalized_bias,
            logour.nmed
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn compressor_accuracy_ranks_propagate_to_multiplier_nmed() {
        let mk = |k| MultFamily::Approx42 {
            compressor: k,
            approx_cols: 8,
        };
        let kong = exhaustive(&mk(CompressorKind::Kong), 8);
        let yang = exhaustive(&mk(CompressorKind::Yang1), 8);
        let dual = exhaustive(&mk(CompressorKind::DualQuality), 8);
        assert!(kong.nmed < yang.nmed, "kong {} yang {}", kong.nmed, yang.nmed);
        assert!(yang.nmed < dual.nmed, "yang {} dual {}", yang.nmed, dual.nmed);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn sampled_agrees_with_exhaustive_roughly() {
        let fam = MultFamily::Mitchell;
        let ex = exhaustive(&fam, 8);
        let sa = sampled(&fam, 8, 40_000, 42);
        assert!(
            (sa.nmed - ex.nmed).abs() / ex.nmed < 0.1,
            "sampled {} vs exhaustive {}",
            sa.nmed,
            ex.nmed
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn wide_multiplier_metrics_finite() {
        let r = sampled(&MultFamily::LogOur, 16, 5_000, 7);
        assert!(r.nmed > 0.0 && r.nmed < 0.1);
        assert!(r.mred > 0.0 && r.mred < 0.2);
    }
}
