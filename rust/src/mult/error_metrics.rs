//! Error metrics for approximate multipliers (paper Tab. IV):
//!
//! * **NMED** — normalized mean error distance: `mean(|p̂ − p|) / p_max`;
//! * **MRED** — mean relative error distance: `mean(|p̂ − p| / p)` over
//!   nonzero exact products;
//! * **ER** — error rate, **WCE** — worst-case error, and the signed bias
//!   (which explains the paper's observation that Log-our's zero-mean
//!   errors behave like noise regularization while Appro4-2's one-sided
//!   errors accumulate).
//!
//! Three characterization paths, all reduced through one accumulator so the
//! metrics definitions cannot drift:
//!
//! * [`exhaustive`] / [`sampled`] — behavioral models (64-lane fast path
//!   for the PP-tree families via `product_table`);
//! * [`exhaustive_sim`] — any [`Simulator`] engine over the gate netlist
//!   (the scalar-vs-bit-parallel comparison in `benches/hotpaths.rs`);
//! * [`exhaustive_netlist`] — the production path: bit-parallel netlist
//!   simulation, partitioned across worker threads by operand range.
//!
//! Exhaustive for widths ≤ 12 bits; seeded uniform sampling above.

use super::behavioral::{behavioral_fn, product_table};
use crate::config::spec::{MultFamily, MultSpec};
use crate::gates::Netlist;
use crate::sim::activity::mult_workload_vectors;
use crate::sim::bitparallel::counting_planes_wide;
use crate::sim::Simulator;
use crate::store::{DesignPointRecord, DesignPointStore, ErrorStats, KeyBuilder};
use crate::util::rng::Pcg32;
use crate::util::threadpool::parallel_map;

/// Full error report for one multiplier configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorReport {
    pub nmed: f64,
    pub mred: f64,
    pub error_rate: f64,
    pub wce: u64,
    /// Signed mean error / p_max — negative = systematic underestimate.
    pub normalized_bias: f64,
    /// Number of (a, b) pairs evaluated.
    pub samples: u64,
}

/// Mergeable partial sums behind every [`ErrorReport`].
#[derive(Clone, Copy, Debug, Default)]
struct Accum {
    abs_sum: f64,
    signed_sum: f64,
    rel_sum: f64,
    rel_n: u64,
    wrong: u64,
    wce: u64,
    samples: u64,
}

impl Accum {
    #[inline]
    fn add(&mut self, exact: i64, got: i64) {
        let err = got - exact;
        if err != 0 {
            self.wrong += 1;
        }
        let ae = err.unsigned_abs();
        self.wce = self.wce.max(ae);
        self.abs_sum += ae as f64;
        self.signed_sum += err as f64;
        if exact != 0 {
            self.rel_sum += ae as f64 / exact as f64;
            self.rel_n += 1;
        }
        self.samples += 1;
    }

    fn merge(mut self, other: Accum) -> Accum {
        self.abs_sum += other.abs_sum;
        self.signed_sum += other.signed_sum;
        self.rel_sum += other.rel_sum;
        self.rel_n += other.rel_n;
        self.wrong += other.wrong;
        self.wce = self.wce.max(other.wce);
        self.samples += other.samples;
        self
    }

    fn finalize(&self, p_max: f64) -> ErrorReport {
        let total = self.samples.max(1) as f64;
        ErrorReport {
            nmed: self.abs_sum / total / p_max,
            mred: self.rel_sum / self.rel_n.max(1) as f64,
            error_rate: self.wrong as f64 / total,
            wce: self.wce,
            normalized_bias: self.signed_sum / total / p_max,
            samples: self.samples,
        }
    }
}

fn p_max(bits: usize) -> f64 {
    let top = (1u128 << bits) - 1;
    (top * top) as f64
}

/// Compute metrics exhaustively over all `2^bits × 2^bits` input pairs
/// through the behavioral model (64-lane `product_table` fast path up to
/// 10 bits, pointwise above).
pub fn exhaustive(family: &MultFamily, bits: usize) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits; use sampled()");
    let n = 1u64 << bits;
    let mut acc = Accum::default();
    if bits <= 10 {
        let table = product_table(family, bits);
        for a in 0..n {
            for b in 0..n {
                let got = table[((a as usize) << bits) | b as usize] as i64;
                acc.add((a * b) as i64, got);
            }
        }
    } else {
        let f = behavioral_fn(family, bits);
        for a in 0..n {
            for b in 0..n {
                acc.add((a * b) as i64, f(a, b) as i64);
            }
        }
    }
    acc.finalize(p_max(bits))
}

/// Sampled metrics for wide multipliers.
pub fn sampled(family: &MultFamily, bits: usize, samples: u64, seed: u64) -> ErrorReport {
    let f = behavioral_fn(family, bits);
    let mut rng = Pcg32::new(seed);
    let mask = ((1u128 << bits) - 1) as u64;
    let mut abs_sum = 0f64;
    let mut signed_sum = 0f64;
    let mut rel_sum = 0f64;
    let mut rel_n = 0u64;
    let mut wrong = 0u64;
    let mut wce = 0u64;
    for _ in 0..samples {
        let a = rng.next_u64() & mask;
        let b = rng.next_u64() & mask;
        let exact = (a as u128 * b as u128) as i128;
        let got = f(a, b) as i128;
        let err = got - exact;
        if err != 0 {
            wrong += 1;
        }
        let ae = err.unsigned_abs() as u64;
        wce = wce.max(ae);
        abs_sum += ae as f64;
        signed_sum += err as f64;
        if exact != 0 {
            rel_sum += ae as f64 / exact as f64;
            rel_n += 1;
        }
    }
    ErrorReport {
        nmed: abs_sum / samples as f64 / p_max(bits),
        mred: rel_sum / rel_n.max(1) as f64,
        error_rate: wrong as f64 / samples as f64,
        wce,
        normalized_bias: signed_sum / samples as f64 / p_max(bits),
        samples,
    }
}

/// Fold a slice of (a, b) pairs through a gate-simulation engine,
/// accumulating error sums against the exact product. The netlist's output
/// bus is read LSB-first in declaration order (every multiplier netlist
/// declares `p[0..2·bits)` that way).
fn accumulate_pairs(sim: &mut dyn Simulator, bits: usize, pairs: &[(u64, u64)], acc: &mut Accum) {
    const BATCH: usize = 4096;
    for chunk in pairs.chunks(BATCH) {
        let vectors = mult_workload_vectors(bits, chunk);
        let outs = sim.run(&vectors);
        for (&(a, b), out) in chunk.iter().zip(&outs) {
            let got = out
                .iter()
                .enumerate()
                .fold(0u64, |p, (i, &bit)| p | ((bit as u64) << i));
            acc.add((a * b) as i64, got as i64);
        }
    }
}

/// Exhaustive characterization of a multiplier *netlist* through any
/// [`Simulator`] engine — the apples-to-apples harness behind the
/// scalar-vs-bit-parallel speedup measurement in `benches/hotpaths.rs`.
pub fn exhaustive_sim(sim: &mut dyn Simulator, bits: usize) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits");
    let n = 1u64 << bits;
    let mut acc = Accum::default();
    let mut pairs = Vec::with_capacity(n as usize);
    for a in 0..n {
        pairs.clear();
        for b in 0..n {
            pairs.push((a, b));
        }
        accumulate_pairs(sim, bits, &pairs, &mut acc);
    }
    acc.finalize(p_max(bits))
}

/// Exhaustive netlist characterization on the bit-plane evaluator,
/// partitioned across `threads` workers by the `a`-operand range (each
/// worker owns its own value buffer over the shared netlist, and the
/// partial sums merge in a fixed order — deterministic for any thread
/// count; the integer-valued metrics are even bit-identical across thread
/// counts). The `b` operand counts through the lanes of a SIMD-wide
/// plane-group via [`counting_planes_wide`] (64 × plane-width vectors per
/// topological sweep, width from [`crate::util::simd::detect`] — results
/// are bit-identical for any width), so no per-vector input or output
/// data is ever materialized — and unlike the [`Simulator`]-trait path
/// this skips toggle accounting, which pure error characterization never
/// reads. This is what the DSE sweep calls per design point.
pub fn exhaustive_netlist(family: &MultFamily, bits: usize, threads: usize) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits; use sampled()");
    let nl = build_mult_netlist(family, bits);
    exhaustive_of_netlist(&nl, bits, threads)
}

/// [`exhaustive_netlist`] consulting the design-point store first: the key
/// is the netlist's canonical structure + the operand width, so a config
/// already characterized by *any* caller (a previous sweep, the `ppa`
/// command, another process sharing the store) is served from disk.
pub fn exhaustive_netlist_cached(
    family: &MultFamily,
    bits: usize,
    threads: usize,
    store: Option<&DesignPointStore>,
) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits; use sampled()");
    let nl = build_mult_netlist(family, bits);
    let Some(store) = store else {
        return exhaustive_of_netlist(&nl, bits, threads);
    };
    let key = KeyBuilder::new("error-exhaustive/1")
        .netlist(&nl)
        .u32(bits as u32)
        .finish();
    let (rec, _hit) = store.get_or_put_with(key, || {
        let report = exhaustive_of_netlist(&nl, bits, threads);
        DesignPointRecord {
            family: family.name(),
            bits: bits as u32,
            n_ops: report.samples,
            error: Some(ErrorStats::from_report(&report)),
            ..Default::default()
        }
    });
    match rec.error {
        Some(e) => e.to_report(),
        None => exhaustive_of_netlist(&nl, bits, threads),
    }
}

/// [`sampled`] consulting the design-point store first. Keyed on the
/// netlist structure (the behavioral model is bit-exact with it) plus the
/// sampling parameters.
pub fn sampled_cached(
    family: &MultFamily,
    bits: usize,
    samples: u64,
    seed: u64,
    store: Option<&DesignPointStore>,
) -> ErrorReport {
    let Some(store) = store else {
        return sampled(family, bits, samples, seed);
    };
    let nl = build_mult_netlist(family, bits);
    let key = KeyBuilder::new("error-sampled/1")
        .netlist(&nl)
        .u32(bits as u32)
        .u64(samples)
        .u64(seed)
        .finish();
    let (rec, _hit) = store.get_or_put_with(key, || {
        let report = sampled(family, bits, samples, seed);
        DesignPointRecord {
            family: family.name(),
            bits: bits as u32,
            n_ops: samples,
            seed,
            error: Some(ErrorStats::from_report(&report)),
            ..Default::default()
        }
    });
    match rec.error {
        Some(e) => e.to_report(),
        None => sampled(family, bits, samples, seed),
    }
}

fn build_mult_netlist(family: &MultFamily, bits: usize) -> Netlist {
    crate::mult::build_netlist(&MultSpec {
        family: family.clone(),
        bits,
        signed: false,
    })
}

fn exhaustive_of_netlist(nl: &Netlist, bits: usize, threads: usize) -> ErrorReport {
    exhaustive_of_netlist_words(nl, bits, threads, crate::util::simd::detect().plane_words())
}

/// [`exhaustive_netlist`] with an explicitly pinned plane-group width
/// (`words == 1` is the scalar-oracle sweep). Exposed for the SIMD
/// equivalence tests and the scalar-vs-SIMD bench columns; results are
/// bit-identical for any `words` at a fixed thread count (integer sums
/// accumulate in the same (a, b) order regardless of the sweep width).
#[doc(hidden)]
pub fn exhaustive_netlist_words(
    family: &MultFamily,
    bits: usize,
    threads: usize,
    words: usize,
) -> ErrorReport {
    assert!(bits <= 12, "exhaustive only up to 12 bits; use sampled()");
    let nl = build_mult_netlist(family, bits);
    exhaustive_of_netlist_words(&nl, bits, threads, words)
}

fn exhaustive_of_netlist_words(
    nl: &Netlist,
    bits: usize,
    threads: usize,
    words: usize,
) -> ErrorReport {
    let out_ids: Vec<usize> = nl.outputs().iter().map(|(_, id)| id.idx()).collect();
    let n = 1u64 << bits;
    // Both n and 64·words are powers of two, so clamping the group width
    // to ceil(n/64) words means every sweep is exactly `words` words and
    // either exactly 64·words lanes or (only when n < 64) n lanes — no
    // partial-word blocks to special-case.
    let words = words.clamp(1, (n as usize).div_ceil(64));
    let stride = 64 * words as u64;
    let threads = threads.max(1).min(n as usize);
    let chunk = (n as usize).div_ceil(threads);
    let parts = parallel_map(threads, threads, |ci| {
        let a_lo = (ci * chunk) as u64;
        let a_hi = ((ci + 1) * chunk).min(n as usize) as u64;
        let mut acc = Accum::default();
        if a_lo >= a_hi {
            return acc;
        }
        // assignment = [a plane-groups (broadcast) | b plane-groups
        // (lane-counting)]; the b planes depend only on the block start,
        // so build the n/stride group sets once instead of per (a, block).
        let b_planes: Vec<Vec<u64>> = (0..n)
            .step_by(stride as usize)
            .map(|b0| counting_planes_wide(b0, bits, words))
            .collect();
        let mut assignment = vec![0u64; 2 * bits * words];
        let mut vals = Vec::new();
        for a in a_lo..a_hi {
            for i in 0..bits {
                let word = if (a >> i) & 1 == 1 { u64::MAX } else { 0 };
                for w in 0..words {
                    assignment[i * words + w] = word;
                }
            }
            let mut b0 = 0u64;
            while b0 < n {
                let lanes = (n - b0).min(stride);
                assignment[bits * words..]
                    .copy_from_slice(&b_planes[(b0 / stride) as usize]);
                nl.eval_wide_into(&assignment, words, &mut vals);
                for lane in 0..lanes {
                    let (w, bit) = ((lane / 64) as usize, lane % 64);
                    let p = out_ids.iter().enumerate().fold(0u64, |p, (i, &idx)| {
                        p | (((vals[idx * words + w] >> bit) & 1) << i)
                    });
                    acc.add((a * (b0 + lane)) as i64, p as i64);
                }
                b0 += lanes;
            }
        }
        acc
    });
    parts
        .into_iter()
        .fold(Accum::default(), Accum::merge)
        .finalize(p_max(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::CompressorKind;
    use crate::sim::{BitParallelSim, EventSim};

    #[test]
    fn exact_families_have_zero_error() {
        for fam in [MultFamily::Exact, MultFamily::AdderTree] {
            let r = exhaustive(&fam, 8);
            assert_eq!(r.nmed, 0.0);
            assert_eq!(r.mred, 0.0);
            assert_eq!(r.error_rate, 0.0);
            assert_eq!(r.wce, 0);
        }
    }

    #[test]
    fn netlist_engine_matches_behavioral_for_pptree_families() {
        // SoftFabric and the gate netlist are the same circuit by
        // construction, so the reports must be identical — not just close.
        for fam in [
            MultFamily::Exact,
            MultFamily::Approx42 {
                compressor: CompressorKind::Yang1,
                approx_cols: 6,
            },
        ] {
            let behavioral = exhaustive(&fam, 6);
            let netlist = exhaustive_netlist(&fam, 6, 2);
            assert_eq!(behavioral.nmed, netlist.nmed, "{fam:?}");
            assert_eq!(behavioral.wce, netlist.wce, "{fam:?}");
            assert_eq!(behavioral.error_rate, netlist.error_rate, "{fam:?}");
            assert_eq!(behavioral.samples, netlist.samples);
        }
    }

    #[test]
    fn netlist_engine_deterministic_across_thread_counts() {
        let fam = MultFamily::Approx42 {
            compressor: CompressorKind::Momeni,
            approx_cols: 6,
        };
        let one = exhaustive_netlist(&fam, 6, 1);
        for threads in [2, 3, 5, 8] {
            let multi = exhaustive_netlist(&fam, 6, threads);
            // nmed/bias sum exactly-representable integers, so they are
            // bit-equal for any partitioning; mred sums ratios, where the
            // merge grouping can shift the last ulp.
            assert_eq!(one.nmed, multi.nmed, "threads={threads}");
            assert_eq!(one.normalized_bias, multi.normalized_bias);
            assert_eq!(one.wce, multi.wce);
            assert_eq!(one.error_rate, multi.error_rate);
            assert!((one.mred - multi.mred).abs() < 1e-12 * one.mred.max(1.0));
        }
    }

    #[test]
    fn cached_characterization_is_bit_identical() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_err_cache_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = crate::store::DesignPointStore::open(&dir).unwrap();
        let fam = MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: 5,
        };
        let plain = exhaustive_netlist(&fam, 5, 2);
        let miss = exhaustive_netlist_cached(&fam, 5, 2, Some(&store));
        let hit = exhaustive_netlist_cached(&fam, 5, 2, Some(&store));
        for r in [&miss, &hit] {
            assert_eq!(r.nmed.to_bits(), plain.nmed.to_bits());
            assert_eq!(r.mred.to_bits(), plain.mred.to_bits());
            assert_eq!(r.wce, plain.wce);
            assert_eq!(r.samples, plain.samples);
        }
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 1, 1));
        // Sampled path caches under its own domain (no cross-domain hit).
        let sa = sampled(&fam, 5, 500, 11);
        let sc = sampled_cached(&fam, 5, 500, 11, Some(&store));
        assert_eq!(sa.nmed.to_bits(), sc.nmed.to_bits());
        assert_eq!(store.stats().writes, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn plane_width_does_not_change_reports() {
        // 8 bits so group widths up to 4 words are actually exercised
        // (n = 256 lanes per a-value). Fixed thread count → the float
        // accumulation order is identical, so even the f64 metrics are
        // bit-equal across widths.
        let fam = MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: 6,
        };
        let narrow = exhaustive_netlist_words(&fam, 8, 2, 1);
        for words in [2usize, 4] {
            let wide = exhaustive_netlist_words(&fam, 8, 2, words);
            assert_eq!(narrow.nmed.to_bits(), wide.nmed.to_bits(), "words={words}");
            assert_eq!(narrow.mred.to_bits(), wide.mred.to_bits(), "words={words}");
            assert_eq!(narrow.wce, wide.wce, "words={words}");
            assert_eq!(narrow.error_rate, wide.error_rate, "words={words}");
            assert_eq!(
                narrow.normalized_bias.to_bits(),
                wide.normalized_bias.to_bits(),
                "words={words}"
            );
            assert_eq!(narrow.samples, wide.samples);
        }
    }

    #[test]
    fn scalar_and_bitparallel_sim_agree_on_reports() {
        let fam = MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: 5,
        };
        let nl = crate::mult::build_netlist(&MultSpec {
            family: fam.clone(),
            bits: 5,
            signed: false,
        });
        let mut scalar = EventSim::new(&nl);
        let mut lanes = BitParallelSim::new(&nl);
        let a = exhaustive_sim(&mut scalar, 5);
        let b = exhaustive_sim(&mut lanes, 5);
        let c = exhaustive_netlist(&fam, 5, 2); // packed fast path
        assert_eq!(a.nmed, b.nmed);
        assert_eq!(a.wce, b.wce);
        assert_eq!(a.error_rate, b.error_rate);
        assert_eq!(scalar.total_toggles(), lanes.total_toggles());
        assert_eq!(a.nmed, c.nmed);
        assert_eq!(a.wce, c.wce);
        assert_eq!(a.normalized_bias, c.normalized_bias);
        assert_eq!(a.samples, c.samples);
        assert!((a.mred - c.mred).abs() < 1e-12 * a.mred.max(1.0));
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn paper_table4_nmed_ordering() {
        // Tab. IV: NMED(Appro4-2) << NMED(Log-our) << NMED(LM[24]).
        let appro = exhaustive(&MultFamily::default_approx(8), 8);
        let logour = exhaustive(&MultFamily::LogOur, 8);
        let lm = exhaustive(&MultFamily::Mitchell, 8);
        assert!(
            appro.nmed < logour.nmed && logour.nmed < lm.nmed,
            "NMED ordering violated: appro={:.3e} logour={:.3e} lm={:.3e}",
            appro.nmed,
            logour.nmed,
            lm.nmed
        );
        // Paper magnitudes (8-bit native): logour ~4.4e-3, lm ~2.8e-2.
        assert!(logour.nmed < 2e-2, "logour nmed {:.3e}", logour.nmed);
        assert!(lm.nmed > 5e-3 && lm.nmed < 8e-2, "lm nmed {:.3e}", lm.nmed);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn appro42_bias_is_one_sided_logour_is_balanced() {
        // The paper's §V-B argument: yang1's errors are one-sided
        // (systematic) while Log-our's are near zero-mean.
        let appro = exhaustive(&MultFamily::default_approx(8), 8);
        let logour = exhaustive(&MultFamily::LogOur, 8);
        assert!(appro.normalized_bias < 0.0);
        assert!(
            appro.normalized_bias.abs() > 0.9 * appro.nmed,
            "appro4-2 errors should be almost fully one-sided"
        );
        assert!(
            logour.normalized_bias.abs() < 0.8 * logour.nmed,
            "log-our errors should partially cancel (bias {:.3e} vs nmed {:.3e})",
            logour.normalized_bias,
            logour.nmed
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn compressor_accuracy_ranks_propagate_to_multiplier_nmed() {
        let mk = |k| MultFamily::Approx42 {
            compressor: k,
            approx_cols: 8,
        };
        let kong = exhaustive(&mk(CompressorKind::Kong), 8);
        let yang = exhaustive(&mk(CompressorKind::Yang1), 8);
        let dual = exhaustive(&mk(CompressorKind::DualQuality), 8);
        assert!(kong.nmed < yang.nmed, "kong {} yang {}", kong.nmed, yang.nmed);
        assert!(yang.nmed < dual.nmed, "yang {} dual {}", yang.nmed, dual.nmed);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn sampled_agrees_with_exhaustive_roughly() {
        let fam = MultFamily::Mitchell;
        let ex = exhaustive(&fam, 8);
        let sa = sampled(&fam, 8, 40_000, 42);
        assert!(
            (sa.nmed - ex.nmed).abs() / ex.nmed < 0.1,
            "sampled {} vs exhaustive {}",
            sa.nmed,
            ex.nmed
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn wide_multiplier_metrics_finite() {
        let r = sampled(&MultFamily::LogOur, 16, 5_000, 7);
        assert!(r.nmed > 0.0 && r.nmed < 0.1);
        assert!(r.mred > 0.0 && r.mred < 0.2);
    }
}
