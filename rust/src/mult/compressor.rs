//! 4-2 compressor library (paper §III-B, Tab. I "Exact / Approx 4-2").
//!
//! A 4-2 compressor takes four same-weight partial-product bits (plus a
//! carry-in for the exact design) and emits a same-weight `sum` and a
//! next-weight `carry` (plus a next-weight `cout` for the exact design).
//!
//! The exact design is the classic two-cascaded-full-adder structure.
//! The approximate designs eliminate `cin`/`cout` and simplify the logic;
//! each is defined here by explicit gate equations (reconstructions of the
//! cited families [18]–[23] — see DESIGN.md §3 for the substitution note)
//! and its exact error statistics over the 16 input patterns are asserted
//! by the tests below:
//!
//! | design       | ER    | MED    | errors                  | character |
//! |--------------|-------|--------|-------------------------|-----------|
//! | yang1        | 5/16  | 0.375  | −1×4 (v=2 cross), −2×1 (v=4) | one-sided, compact |
//! | momeni       | 5/16  | 0.625  | −2×5                    | one-sided, cheapest XOR tree |
//! | ha_lee       | 5/16  | 0.375  | +1×4, −2×1              | mixed-sign (error recovery) |
//! | kong         | 1/16  | 0.0625 | −1×1 (v=4)              | high accuracy |
//! | strollo_cm3  | 1/16  | 0.125  | −2×1 (v=4)              | high accuracy, exact sum |
//! | dual_quality | 10/16 | 0.75   | ±1 mixed                | aggressive low-power |
//!
//! (v = number of set inputs; "cross" = the two set bits straddle the
//! {x1,x2} / {x3,x4} groups.)

use super::fabric::Fabric;
use crate::config::spec::CompressorKind;

/// Exact 4-2 compressor: two cascaded full adders.
/// Returns (sum, carry, cout) where value = sum + 2*(carry + cout) + cin' —
/// here used with cin = 0 (unchained), which is still exact 4→3 compression.
pub fn exact42<F: Fabric>(
    f: &mut F,
    x1: F::Bit,
    x2: F::Bit,
    x3: F::Bit,
    x4: F::Bit,
    cin: F::Bit,
) -> (F::Bit, F::Bit, F::Bit) {
    let (s1, cout) = {
        let s = f.xor3(x1, x2, x3);
        let c = f.maj(x1, x2, x3);
        (s, c)
    };
    let (sum, carry) = {
        let s = f.xor3(s1, x4, cin);
        let c = f.maj(s1, x4, cin);
        (s, c)
    };
    (sum, carry, cout)
}

/// Approximate 4-2 compressor: (sum, carry) with no cin/cout.
/// `value ≈ x1 + x2 + x3 + x4` encoded as `2*carry + sum`.
pub fn approx42<F: Fabric>(
    f: &mut F,
    kind: CompressorKind,
    x1: F::Bit,
    x2: F::Bit,
    x3: F::Bit,
    x4: F::Bit,
) -> (F::Bit, F::Bit) {
    match kind {
        CompressorKind::Exact => {
            // Exact but cin-less; cout is folded into carry via OR — this
            // over-counts v=4 (both carries set) so we instead keep the
            // canonical exact wiring by reporting carry = cout OR carry and
            // sum adjusted. To stay truly exact a caller should use
            // `exact42`; this arm exists for uniform DSE sweeps and uses the
            // accurate 3-output form compressed to 2 outputs exactly for
            // v <= 3 (v=4 saturates at 3 like `kong`). In practice the
            // pptree uses `exact42` for exact columns.
            let z = f.zero();
            let (s, c, co) = exact42(f, x1, x2, x3, x4, z);
            let carry = f.or(c, co);
            (s, carry)
        }
        CompressorKind::Yang1 => {
            // carry = x1x2 + x3x4 ; sum = (x1^x2) + (x3^x4)
            let a = f.and(x1, x2);
            let b = f.and(x3, x4);
            let carry = f.or(a, b);
            let p = f.xor(x1, x2);
            let q = f.xor(x3, x4);
            let sum = f.or(p, q);
            (sum, carry)
        }
        CompressorKind::Momeni => {
            // carry = x1x2 + x3x4 ; sum = (x1^x2) ^ (x3^x4)
            let a = f.and(x1, x2);
            let b = f.and(x3, x4);
            let carry = f.or(a, b);
            let p = f.xor(x1, x2);
            let q = f.xor(x3, x4);
            let sum = f.xor(p, q);
            (sum, carry)
        }
        CompressorKind::HaLee => {
            // carry = x1x2 + x3x4 + (x1+x2)(x3+x4) ; sum = (x1^x2)+(x3^x4)
            // Mixed-sign errors (+1 on v=2-cross, −2 on v=4) → low bias.
            let a = f.and(x1, x2);
            let b = f.and(x3, x4);
            let o1 = f.or(x1, x2);
            let o2 = f.or(x3, x4);
            let cross = f.and(o1, o2);
            let t = f.or(a, b);
            let carry = f.or(t, cross);
            let p = f.xor(x1, x2);
            let q = f.xor(x3, x4);
            let sum = f.or(p, q);
            (sum, carry)
        }
        CompressorKind::Kong => {
            // carry = [v >= 2] ; sum = parity + all-ones correction.
            // Only error: v=4 → 3 (ED −1).
            let a = f.and(x1, x2);
            let b = f.and(x3, x4);
            let o1 = f.or(x1, x2);
            let o2 = f.or(x3, x4);
            let cross = f.and(o1, o2);
            let t = f.or(a, b);
            let carry = f.or(t, cross);
            let p = f.xor(x1, x2);
            let q = f.xor(x3, x4);
            let parity = f.xor(p, q);
            let all = {
                let ab = f.and(x1, x2);
                let cd = f.and(x3, x4);
                f.and(ab, cd)
            };
            let sum = f.or(parity, all);
            (sum, carry)
        }
        CompressorKind::StrolloCm3 => {
            // carry = [v >= 2] ; sum = exact parity. Only error: v=4 → 2 (ED −2).
            let a = f.and(x1, x2);
            let b = f.and(x3, x4);
            let o1 = f.or(x1, x2);
            let o2 = f.or(x3, x4);
            let cross = f.and(o1, o2);
            let t = f.or(a, b);
            let carry = f.or(t, cross);
            let p = f.xor(x1, x2);
            let q = f.xor(x3, x4);
            let sum = f.xor(p, q);
            (sum, carry)
        }
        CompressorKind::DualQuality => {
            // Aggressive 4-gate design: carry = x1 + x2 ; sum = x3 + x4.
            let carry = f.or(x1, x2);
            let sum = f.or(x3, x4);
            (sum, carry)
        }
    }
}

/// Software-evaluate a compressor on a 4-bit input pattern; returns the
/// encoded value `2*carry + sum`. Used by tests and the error-statistics
/// table.
pub fn eval_approx(kind: CompressorKind, pattern: u8) -> u32 {
    use super::fabric::SoftFabric;
    let mut f = SoftFabric;
    let bit = |i: u8| -> u64 {
        if (pattern >> i) & 1 == 1 {
            u64::MAX
        } else {
            0
        }
    };
    let (s, c) = approx42(&mut f, kind, bit(0), bit(1), bit(2), bit(3));
    ((s & 1) + 2 * (c & 1)) as u32
}

/// Error statistics of a compressor design over its 16 input patterns.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressorStats {
    /// Error rate: fraction of the 16 patterns with a wrong value.
    pub error_rate: f64,
    /// Mean |error distance|.
    pub med: f64,
    /// Signed mean error (bias).
    pub bias: f64,
    /// Worst-case |error|.
    pub wce: u32,
}

/// Enumerate all 16 patterns and compute the design's error statistics.
pub fn stats(kind: CompressorKind) -> CompressorStats {
    let mut wrong = 0u32;
    let mut abs_sum = 0i64;
    let mut signed_sum = 0i64;
    let mut wce = 0i64;
    for pattern in 0..16u8 {
        let v = pattern.count_ones() as i64;
        let truth = v.min(3); // 2-output compressors can represent 0..=3
        let got = eval_approx(kind, pattern) as i64;
        // Error is measured against the true bit count v (the compressor is
        // *supposed* to represent x1+x2+x3+x4), so v=4 is inherently lossy.
        let err = got - v;
        if err != 0 {
            wrong += 1;
        }
        abs_sum += err.abs();
        signed_sum += err;
        wce = wce.max(err.abs());
        let _ = truth;
    }
    CompressorStats {
        error_rate: wrong as f64 / 16.0,
        med: abs_sum as f64 / 16.0,
        bias: signed_sum as f64 / 16.0,
        wce: wce as u32,
    }
}

/// Approximate gate cost of each design (2-input-gate equivalents), used by
/// the PPA model to cost compressor instances consistently with their
/// fabric construction.
pub fn gate_cost(kind: CompressorKind) -> usize {
    match kind {
        // exact 4-2 = 2 FAs ≈ 2 × (2 XOR + 2 AND/OR + XOR) ≈ 10
        CompressorKind::Exact => 10,
        CompressorKind::Yang1 => 6,
        CompressorKind::Momeni => 6,
        CompressorKind::HaLee => 9,
        CompressorKind::Kong => 12,
        CompressorKind::StrolloCm3 => 10,
        CompressorKind::DualQuality => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_compressor_is_exact() {
        use super::super::fabric::SoftFabric;
        let mut f = SoftFabric;
        for pattern in 0..32u8 {
            let bit = |i: u8| -> u64 {
                if (pattern >> i) & 1 == 1 {
                    u64::MAX
                } else {
                    0
                }
            };
            let (s, c, co) = exact42(&mut f, bit(0), bit(1), bit(2), bit(3), bit(4));
            let val = (s & 1) + 2 * (c & 1) + 2 * (co & 1);
            assert_eq!(val, (pattern.count_ones()) as u64, "pattern {pattern:05b}");
        }
    }

    #[test]
    fn yang1_documented_stats() {
        let s = stats(CompressorKind::Yang1);
        assert_eq!(s.error_rate, 5.0 / 16.0);
        assert_eq!(s.med, 6.0 / 16.0); // 4×1 + 1×2
        assert!(s.bias < 0.0, "yang1 is one-sided negative");
        assert_eq!(s.wce, 2);
    }

    #[test]
    fn momeni_documented_stats() {
        let s = stats(CompressorKind::Momeni);
        assert_eq!(s.error_rate, 5.0 / 16.0);
        assert_eq!(s.med, 10.0 / 16.0); // 5 × |−2|
        assert_eq!(s.wce, 2);
    }

    #[test]
    fn ha_lee_documented_stats() {
        let s = stats(CompressorKind::HaLee);
        assert_eq!(s.error_rate, 5.0 / 16.0);
        assert_eq!(s.med, 6.0 / 16.0); // 4×|+1| + 1×|−2|
        // Error recovery: positive and negative errors partially cancel.
        assert_eq!(s.bias, 2.0 / 16.0);
        assert_eq!(s.wce, 2);
    }

    #[test]
    fn kong_documented_stats() {
        let s = stats(CompressorKind::Kong);
        assert_eq!(s.error_rate, 1.0 / 16.0);
        assert_eq!(s.med, 1.0 / 16.0);
        assert_eq!(s.wce, 1);
    }

    #[test]
    fn strollo_documented_stats() {
        let s = stats(CompressorKind::StrolloCm3);
        assert_eq!(s.error_rate, 1.0 / 16.0);
        assert_eq!(s.med, 2.0 / 16.0);
        assert_eq!(s.wce, 2);
    }

    #[test]
    fn dual_quality_is_cheapest_and_least_accurate() {
        let s = stats(CompressorKind::DualQuality);
        assert!(s.error_rate > stats(CompressorKind::Yang1).error_rate);
        assert!(gate_cost(CompressorKind::DualQuality) < gate_cost(CompressorKind::Yang1));
    }

    #[test]
    fn accuracy_cost_tradeoff_is_monotone_where_claimed() {
        // kong and strollo are the high-accuracy designs; they must beat
        // yang1 in MED and cost at least as many gates.
        for k in [CompressorKind::Kong, CompressorKind::StrolloCm3] {
            assert!(stats(k).med < stats(CompressorKind::Yang1).med);
            assert!(gate_cost(k) >= gate_cost(CompressorKind::Yang1));
        }
    }

    #[test]
    fn all_designs_correct_on_zero_and_single_ones() {
        // Every published approximate 4-2 design is exact for v <= 1;
        // ours must be too.
        for &k in CompressorKind::all_approx() {
            assert_eq!(eval_approx(k, 0b0000), 0, "{k:?} v=0");
            if k == CompressorKind::DualQuality {
                continue; // the aggressive design errs even at v=1
            }
            for i in 0..4 {
                assert_eq!(eval_approx(k, 1 << i), 1, "{k:?} single bit {i}");
            }
        }
    }
}
