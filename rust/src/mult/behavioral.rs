//! Unified behavioral models: one `Fn(u64, u64) -> u64` per family, the
//! sign-magnitude wrapper used by the signed applications (edge detection,
//! NN), and LUT generation for the Python/Pallas emulation path.

use super::logarithmic::{logour_behavioral, mitchell_behavioral};
use super::pptree;
use crate::config::spec::MultFamily;
use crate::util::npy::NpyArray;

/// Unsigned behavioral multiply for a family at a given width.
pub fn behavioral_fn(
    family: &MultFamily,
    bits: usize,
) -> Box<dyn Fn(u64, u64) -> u64 + Send + Sync> {
    match family {
        MultFamily::Exact | MultFamily::AdderTree => Box::new(move |a, b| {
            debug_assert!(a < (1 << bits) && b < (1 << bits));
            a * b
        }),
        MultFamily::Approx42 {
            compressor,
            approx_cols,
        } => {
            let kind = *compressor;
            let cols = *approx_cols;
            Box::new(move |a, b| pptree::soft_multiply(bits, cols, Some(kind), a, b))
        }
        MultFamily::LogOur => Box::new(move |a, b| logour_behavioral(bits, a, b)),
        MultFamily::Mitchell => Box::new(move |a, b| mitchell_behavioral(bits, a, b)),
    }
}

/// Exhaustive unsigned product table for `bits`-bit operands
/// (`table[a << bits | b] = family(a, b)`). 8-bit → 65536 entries.
/// Uses the 64-lane evaluator for the PP-tree families.
pub fn product_table(family: &MultFamily, bits: usize) -> Vec<u64> {
    let n = 1usize << bits;
    match family {
        MultFamily::Approx42 {
            compressor,
            approx_cols,
        } => {
            // 64-lane fast path.
            let mut out = vec![0u64; n * n];
            let mut pa = Vec::with_capacity(64);
            let mut pb = Vec::with_capacity(64);
            let mut idx = Vec::with_capacity(64);
            let flush = |pa: &mut Vec<u64>, pb: &mut Vec<u64>, idx: &mut Vec<usize>, out: &mut Vec<u64>| {
                if pa.is_empty() {
                    return;
                }
                let prods = pptree::soft_multiply_lanes(
                    bits,
                    *approx_cols,
                    Some(*compressor),
                    pa,
                    pb,
                );
                for (&i, p) in idx.iter().zip(prods) {
                    out[i] = p;
                }
                pa.clear();
                pb.clear();
                idx.clear();
            };
            for a in 0..n as u64 {
                for b in 0..n as u64 {
                    pa.push(a);
                    pb.push(b);
                    idx.push(((a as usize) << bits) | b as usize);
                    if pa.len() == 64 {
                        flush(&mut pa, &mut pb, &mut idx, &mut out);
                    }
                }
            }
            flush(&mut pa, &mut pb, &mut idx, &mut out);
            out
        }
        _ => {
            let f = behavioral_fn(family, bits);
            let mut out = vec![0u64; n * n];
            for a in 0..n as u64 {
                for b in 0..n as u64 {
                    out[((a as usize) << bits) | b as usize] = f(a, b);
                }
            }
            out
        }
    }
}

/// Signed multiply via sign-magnitude wrapping of the unsigned family
/// (standard practice for approximate-multiplier applications): the product
/// sign is `sign(a) XOR sign(b)`, the magnitude goes through the unsigned
/// `bits`-bit multiplier. Magnitudes must fit `bits` bits (|−2^(bits−1)| =
/// 2^(bits−1) does fit).
pub fn signed_multiply(f: &dyn Fn(u64, u64) -> u64, a: i64, b: i64) -> i64 {
    let neg = (a < 0) ^ (b < 0);
    let p = f(a.unsigned_abs(), b.unsigned_abs()) as i64;
    if neg {
        -p
    } else {
        p
    }
}

/// The int8×int8 → i32 LUT consumed by the Pallas kernel: indexed by
/// `(a & 0xFF) << 8 | (b & 0xFF)` where a, b are the int8 two's-complement
/// bit patterns. Products are computed sign-magnitude through the unsigned
/// 8-bit behavioral multiplier.
///
/// Built from the unsigned [`product_table`] (64-lane bit-parallel for the
/// PP-tree families — ~50× faster than pointwise evaluation; §Perf in
/// EXPERIMENTS.md) and folded to sign-magnitude. |−128| = 128 needs one
/// extra unsigned column, handled by a 9-bit-safe direct evaluation.
pub fn int8_lut(family: &MultFamily) -> Vec<i32> {
    let table = product_table(family, 8); // unsigned |a|×|b| for 0..=255
    let f = behavioral_fn(family, 8);
    let mut lut = vec![0i32; 65536];
    for a in -128i64..=127 {
        let am = a.unsigned_abs();
        for b in -128i64..=127 {
            let bm = b.unsigned_abs();
            let idx = (((a as u8) as usize) << 8) | ((b as u8) as usize);
            // 128 is a valid unsigned 8-bit operand (2^7 exactly), so the
            // 256×256 table covers all magnitudes 0..=128.
            let mag = if am <= 255 && bm <= 255 {
                table[((am as usize) << 8) | bm as usize] as i64
            } else {
                f(am, bm) as i64
            };
            let p = if (a < 0) ^ (b < 0) { -mag } else { mag };
            lut[idx] = p as i32;
        }
    }
    lut
}

/// Unsigned 8-bit LUT (used by image blending).
pub fn uint8_lut(family: &MultFamily) -> Vec<i32> {
    product_table(family, 8).iter().map(|&p| p as i32).collect()
}

/// Serialize an int8 LUT as a (256, 256) npy i32 array.
pub fn lut_to_npy(lut: &[i32]) -> NpyArray {
    assert_eq!(lut.len(), 65536);
    NpyArray::from_i32(&[256, 256], lut)
}

/// The four Table III/IV families with the paper's default configuration.
pub fn paper_families() -> Vec<(String, MultFamily)> {
    vec![
        ("exact".to_string(), MultFamily::Exact),
        ("appro42".to_string(), MultFamily::default_approx(8)),
        ("logour".to_string(), MultFamily::LogOur),
        ("lm".to_string(), MultFamily::Mitchell),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::CompressorKind;

    #[test]
    fn behavioral_dispatch_matches_families() {
        let exact = behavioral_fn(&MultFamily::Exact, 8);
        assert_eq!(exact(200, 100), 20000);
        let lm = behavioral_fn(&MultFamily::Mitchell, 8);
        assert_eq!(lm(128, 64), 8192); // powers of two are exact
        let lo = behavioral_fn(&MultFamily::LogOur, 8);
        assert_eq!(lo(128, 64), 8192);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn product_table_matches_pointwise_fn() {
        let fam = MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: 8,
        };
        let table = product_table(&fam, 8);
        let f = behavioral_fn(&fam, 8);
        for a in (0..256u64).step_by(23) {
            for b in (0..256u64).step_by(29) {
                assert_eq!(table[((a as usize) << 8) | b as usize], f(a, b));
            }
        }
    }

    #[test]
    fn signed_wrapper_quadrants() {
        let f = behavioral_fn(&MultFamily::Exact, 8);
        assert_eq!(signed_multiply(&*f, 5, 7), 35);
        assert_eq!(signed_multiply(&*f, -5, 7), -35);
        assert_eq!(signed_multiply(&*f, 5, -7), -35);
        assert_eq!(signed_multiply(&*f, -5, -7), 35);
        assert_eq!(signed_multiply(&*f, -128, 127), -16256);
        assert_eq!(signed_multiply(&*f, -128, -128), 16384);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn int8_lut_exact_family_is_true_product() {
        let lut = int8_lut(&MultFamily::Exact);
        for a in -128i64..=127 {
            for b in (-128i64..=127).step_by(7) {
                let idx = (((a as u8) as usize) << 8) | ((b as u8) as usize);
                assert_eq!(lut[idx] as i64, a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn int8_lut_symmetry_for_symmetric_families() {
        // sign-magnitude wrapping ⇒ lut(a,b) = -lut(-a,b) for a != -128.
        let lut = int8_lut(&MultFamily::LogOur);
        for a in -127i64..=127 {
            for b in (-127i64..=127).step_by(11) {
                let i1 = (((a as u8) as usize) << 8) | ((b as u8) as usize);
                let i2 = ((((-a) as u8) as usize) << 8) | ((b as u8) as usize);
                assert_eq!(lut[i1], -lut[i2], "a={a} b={b}");
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn npy_lut_shape() {
        let lut = int8_lut(&MultFamily::Exact);
        let arr = lut_to_npy(&lut);
        assert_eq!(arr.shape, vec![256, 256]);
    }
}
