//! The accuracy-configurable multiplier library — the paper's key
//! contribution (§III-B, §III-C).
//!
//! Five families, all generated for arbitrary bit widths:
//!
//! | family       | paper role                                    |
//! |--------------|-----------------------------------------------|
//! | `Exact`      | exact 4-2-compressor (Dadda) multiplier       |
//! | `Approx42`   | tunable approximate multiplier (Fig 2)        |
//! | `LogOur`     | proposed logarithmic multiplier (Fig 3, Eq 3) |
//! | `Mitchell`   | conventional LM [24] baseline                 |
//! | `AdderTree`  | OpenC²-style adder-tree baseline              |
//!
//! Partial-product-tree families are written once against the [`fabric`]
//! abstraction and instantiated both as gate netlists (for PPA / Verilog /
//! flow) and as 64-lane bit-parallel software evaluators (for LUTs, error
//! metrics and the image/NN applications). The logarithmic families have
//! hand-built netlists (LOD + priority encoders + barrel shifters + COMP +
//! OR-merge) checked exhaustively against independent integer behavioral
//! models.

pub mod fabric;
pub mod compressor;
pub mod pptree;
pub mod logarithmic;
pub mod behavioral;
pub mod error_metrics;
pub mod cli;

use crate::config::spec::{MultFamily, MultSpec};
use crate::gates::Netlist;

/// Build the gate-level netlist for a multiplier spec.
pub fn build_netlist(spec: &MultSpec) -> Netlist {
    assert!(
        !spec.signed,
        "netlist generation targets the unsigned datapath; signed operation \
         is a sign-magnitude wrapper handled at the PE level"
    );
    match &spec.family {
        MultFamily::Exact => pptree::build_exact(spec.bits),
        MultFamily::Approx42 {
            compressor,
            approx_cols,
        } => pptree::build_approx42(spec.bits, *compressor, *approx_cols),
        MultFamily::AdderTree => pptree::build_adder_tree(spec.bits),
        MultFamily::LogOur => logarithmic::build_logour(spec.bits),
        MultFamily::Mitchell => logarithmic::build_mitchell(spec.bits),
    }
}

/// Unsigned behavioral model: `f(a, b) -> product` for the family at the
/// given width. Bit-exact with the netlist (tested exhaustively at 8 bits).
pub fn behavioral(
    family: &MultFamily,
    bits: usize,
) -> Box<dyn Fn(u64, u64) -> u64 + Send + Sync> {
    behavioral::behavioral_fn(family, bits)
}
