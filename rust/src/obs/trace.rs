//! End-to-end request tracing with tail-based sampling.
//!
//! Every admitted request gets a **trace context**: a process-unique id
//! plus monotonic stage timestamps (µs since a process-wide epoch)
//! stamped at the pipeline's admission → batch-close → execute → respond
//! boundaries. The context is a few `u64`s carried inside the queued
//! request — no allocation, no locks on the hot path, and when tracing is
//! disabled (`OPENACM_TRACE=0`) the id is 0 and every stamp is a no-op
//! with zero clock reads, which is what keeps the ≤2% instrumentation
//! guard in `benches/nn_forward.rs` honest.
//!
//! **Tail-based sampling** decides *at completion time* what to keep,
//! so the interesting requests always survive:
//!
//! * every shed, failed, deadline-missed request — bounded ring of
//!   [`FAILURE_CAP`]; overflow is counted (`trace.failures_dropped`) and
//!   logged, never silent;
//! * the top-[`SLOWEST_K`] slowest delivered requests (kept via an atomic
//!   latency floor so the common fast path takes no lock);
//! * 1-in-[`SAMPLE_EVERY`] healthy requests as a behaviour baseline,
//!   bounded ring of [`SAMPLED_CAP`].
//!
//! Kept timelines export as Chrome trace-event JSON
//! (`$OPENACM_OBS/trace.json`, load in `chrome://tracing` / Perfetto) via
//! [`export_chrome`]; `openacm obs trace` renders them in the terminal.
//! The responder also tags `serve.latency_us` histogram buckets with
//! exemplar trace ids ([`super::registry::Histogram::record_with_exemplar`])
//! so a p99 read links to a concrete offending request.

use std::collections::VecDeque;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use super::span::trace_enabled;

/// Bound on retained failure-class timelines (sheds, deadline misses,
/// execute failures). Sized above the CI smoke soak (60k requests) so
/// "every failure has a timeline" holds there; past it, drops are counted.
pub const FAILURE_CAP: usize = 1 << 17;
/// How many slowest delivered requests keep their full timeline.
pub const SLOWEST_K: usize = 64;
/// Healthy requests sampled 1-in-N by trace id (deterministic).
pub const SAMPLE_EVERY: u64 = 64;
/// Bound on retained healthy-sample timelines.
pub const SAMPLED_CAP: usize = 4096;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (first call fixes zero).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate the next trace id; 0 (the "untraced" id) when tracing is
/// disabled, which turns every downstream stamp and keep-decision into a
/// no-op.
pub fn next_id() -> u64 {
    if !trace_enabled() {
        return 0;
    }
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// The id the *next* trace will receive — lets tests scope assertions to
/// traces created after a point in time.
pub fn id_watermark() -> u64 {
    NEXT_ID.load(Ordering::Relaxed)
}

/// How a traced request left the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOutcome {
    Delivered,
    /// Rejected at admission or by a full ingress/stage queue.
    Shed,
    DeadlineExpired,
    ExecuteFailed,
    WorkerPanicked,
}

impl TraceOutcome {
    pub fn name(self) -> &'static str {
        match self {
            TraceOutcome::Delivered => "delivered",
            TraceOutcome::Shed => "shed",
            TraceOutcome::DeadlineExpired => "deadline_expired",
            TraceOutcome::ExecuteFailed => "execute_failed",
            TraceOutcome::WorkerPanicked => "worker_panicked",
        }
    }

    pub fn is_failure(self) -> bool {
        !matches!(self, TraceOutcome::Delivered)
    }
}

/// The in-flight trace context carried inside a queued request: the id
/// plus stage timestamps stamped as the request crosses pipeline
/// boundaries. `Copy`, all-`u64`, zero-allocation; every method is a
/// no-op when `id == 0` (tracing disabled).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStamps {
    pub id: u64,
    /// Admission accepted the request (also the queue-enter time).
    pub t_admit: u64,
    /// The deadline-bucket batcher closed this request's batch.
    pub t_batch: u64,
    /// Executor began / finished the batch containing this request.
    pub t_exec_start: u64,
    pub t_exec_end: u64,
}

impl StageStamps {
    /// Open a trace context at admission. Free (id 0, no clock read) when
    /// tracing is disabled.
    pub fn begin() -> StageStamps {
        let id = next_id();
        if id == 0 {
            return StageStamps::default();
        }
        StageStamps {
            id,
            t_admit: now_us(),
            ..StageStamps::default()
        }
    }

    #[inline]
    pub fn stamp_batch(&mut self, t: u64) {
        if self.id != 0 {
            self.t_batch = t;
        }
    }

    #[inline]
    pub fn stamp_exec(&mut self, start: u64, end: u64) {
        if self.id != 0 {
            self.t_exec_start = start;
            self.t_exec_end = end;
        }
    }

    /// Close the timeline into a [`RequestTrace`] ready for the collector.
    pub fn finish(
        self,
        shard: u32,
        variant: &str,
        outcome: TraceOutcome,
        t_done: u64,
    ) -> RequestTrace {
        RequestTrace {
            id: self.id,
            shard,
            variant: variant.to_string(),
            outcome,
            t_admit: self.t_admit,
            t_batch: self.t_batch,
            t_exec_start: self.t_exec_start,
            t_exec_end: self.t_exec_end,
            t_done,
        }
    }
}

/// One completed request timeline (timestamps in µs since the process
/// trace epoch; 0 = the request never reached that stage).
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    pub shard: u32,
    pub variant: String,
    pub outcome: TraceOutcome,
    pub t_admit: u64,
    pub t_batch: u64,
    pub t_exec_start: u64,
    pub t_exec_end: u64,
    pub t_done: u64,
}

impl RequestTrace {
    /// Admission-to-completion wall time.
    pub fn latency_us(&self) -> u64 {
        self.t_done.saturating_sub(self.t_admit)
    }
}

/// Point-in-time view of everything the collector kept.
#[derive(Clone, Debug, Default)]
pub struct TraceSnapshot {
    /// Every failure-class timeline, oldest first (bounded; see
    /// `failures_dropped`).
    pub failures: Vec<RequestTrace>,
    /// Slowest delivered requests, slowest first.
    pub slowest: Vec<RequestTrace>,
    /// Probabilistic healthy sample, oldest first.
    pub sampled: Vec<RequestTrace>,
    /// Failure timelines evicted because the ring was full.
    pub failures_dropped: u64,
}

impl TraceSnapshot {
    /// All kept traces: failures, then slowest, then sampled.
    pub fn all(&self) -> Vec<&RequestTrace> {
        self.failures
            .iter()
            .chain(self.slowest.iter())
            .chain(self.sampled.iter())
            .collect()
    }
}

#[derive(Default)]
struct CollectorState {
    failures: VecDeque<RequestTrace>,
    failures_dropped: u64,
    /// Unsorted; bounded at [`SLOWEST_K`] by min-replacement.
    slowest: Vec<RequestTrace>,
    sampled: VecDeque<RequestTrace>,
}

/// The process-wide tail-sampling trace collector.
pub struct TraceCollector {
    state: Mutex<CollectorState>,
    /// Latency (µs) of the fastest request currently in `slowest` once it
    /// is full — delivered requests at or below the floor that are not
    /// sampled skip the lock entirely.
    floor: AtomicU64,
}

impl TraceCollector {
    fn new() -> TraceCollector {
        TraceCollector {
            state: Mutex::new(CollectorState::default()),
            floor: AtomicU64::new(0),
        }
    }

    /// Submit a completed timeline; the tail-sampling keep decision
    /// happens here. No-op for untraced (`id == 0`) requests.
    pub fn complete(&self, t: RequestTrace) {
        if t.id == 0 {
            return;
        }
        if t.outcome.is_failure() {
            let mut g = self.state.lock().unwrap();
            if g.failures.len() >= FAILURE_CAP {
                g.failures.pop_front();
                g.failures_dropped += 1;
                if g.failures_dropped == 1 {
                    super::warn(
                        "trace",
                        "failure timeline ring full; evicting oldest",
                        &[("cap", FAILURE_CAP.to_string())],
                    );
                }
                super::counter("trace.failures_dropped").inc();
            }
            g.failures.push_back(t);
            return;
        }
        let latency = t.latency_us();
        let sampled = t.id % SAMPLE_EVERY == 0;
        if !sampled && latency <= self.floor.load(Ordering::Relaxed) {
            return;
        }
        let mut g = self.state.lock().unwrap();
        if sampled {
            if g.sampled.len() >= SAMPLED_CAP {
                g.sampled.pop_front();
            }
            g.sampled.push_back(t.clone());
        }
        if g.slowest.len() < SLOWEST_K {
            g.slowest.push(t);
        } else {
            let (mi, min_lat) = g
                .slowest
                .iter()
                .enumerate()
                .map(|(i, r)| (i, r.latency_us()))
                .min_by_key(|&(_, l)| l)
                .expect("slowest is non-empty");
            if latency > min_lat {
                g.slowest[mi] = t;
            }
        }
        if g.slowest.len() >= SLOWEST_K {
            let floor = g
                .slowest
                .iter()
                .map(RequestTrace::latency_us)
                .min()
                .unwrap_or(0);
            self.floor.store(floor, Ordering::Relaxed);
        }
    }

    /// Clone out everything currently kept.
    pub fn snapshot(&self) -> TraceSnapshot {
        let g = self.state.lock().unwrap();
        let mut slowest: Vec<RequestTrace> = g.slowest.clone();
        slowest.sort_by_key(|r| std::cmp::Reverse(r.latency_us()));
        TraceSnapshot {
            failures: g.failures.iter().cloned().collect(),
            slowest,
            sampled: g.sampled.iter().cloned().collect(),
            failures_dropped: g.failures_dropped,
        }
    }

    /// [`Self::snapshot`], then reset the collector (tests; long soaks
    /// that want per-phase trace files).
    pub fn take(&self) -> TraceSnapshot {
        let snap = {
            let mut g = self.state.lock().unwrap();
            let snap = CollectorState {
                failures: std::mem::take(&mut g.failures),
                failures_dropped: std::mem::take(&mut g.failures_dropped),
                slowest: std::mem::take(&mut g.slowest),
                sampled: std::mem::take(&mut g.sampled),
            };
            self.floor.store(0, Ordering::Relaxed);
            snap
        };
        let mut slowest = snap.slowest;
        slowest.sort_by_key(|r| std::cmp::Reverse(r.latency_us()));
        TraceSnapshot {
            failures: snap.failures.into_iter().collect(),
            slowest,
            sampled: snap.sampled.into_iter().collect(),
            failures_dropped: snap.failures_dropped,
        }
    }
}

/// The process-wide collector every pipeline reports through.
pub fn collector() -> &'static TraceCollector {
    static GLOBAL: OnceLock<TraceCollector> = OnceLock::new();
    GLOBAL.get_or_init(TraceCollector::new)
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn push_event(out: &mut Vec<String>, t: &RequestTrace, stage: &str, ts: u64, end: u64) {
    out.push(format!(
        "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
         \"pid\":1,\"tid\":{},\"args\":{{\"trace\":{},\"variant\":\"{}\",\"outcome\":\"{}\"}}}}",
        stage,
        ts,
        end.saturating_sub(ts),
        t.shard,
        t.id,
        esc(&t.variant),
        t.outcome.name()
    ))
}

/// Render one timeline as its Chrome trace-event stage slices:
/// `queue` (admit → batch-close), `execute`, `respond`. Stages the
/// request never reached are omitted; a shed request collapses to a
/// zero-length `queue` slice whose `args.outcome` says why.
fn chrome_events(t: &RequestTrace, out: &mut Vec<String>) {
    let queue_end = if t.t_batch > 0 { t.t_batch } else { t.t_done };
    if queue_end >= t.t_admit {
        push_event(out, t, "queue", t.t_admit, queue_end);
    }
    if t.t_exec_start > 0 && t.t_exec_end >= t.t_exec_start {
        push_event(out, t, "execute", t.t_exec_start, t.t_exec_end);
    }
    let resp_start = if t.t_exec_end > 0 {
        t.t_exec_end
    } else if t.t_batch > 0 {
        t.t_batch
    } else {
        t.t_admit
    };
    if t.t_done >= resp_start && (t.t_exec_end > 0 || t.t_batch > 0) {
        push_event(out, t, "respond", resp_start, t.t_done);
    }
}

/// Serialize a trace snapshot as Chrome trace-event JSON.
pub fn to_chrome_json(snap: &TraceSnapshot) -> String {
    let mut events: Vec<String> = Vec::new();
    for t in snap.all() {
        chrome_events(t, &mut events);
    }
    let mut s = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    s.push_str(&events.join(",\n"));
    s.push_str("\n]}\n");
    s
}

/// Write the collector's kept timelines to `<dir>/trace.json` (Chrome
/// trace-event format), atomically (temp file + rename), and return the
/// path. The collector is left intact, so periodic exports accumulate.
pub fn export_chrome(dir: &Path) -> Result<PathBuf> {
    fs::create_dir_all(dir)
        .with_context(|| format!("creating obs dir {}", dir.display()))?;
    let snap = collector().snapshot();
    let path = dir.join("trace.json");
    let tmp = dir.join(".trace.json.tmp");
    fs::write(&tmp, to_chrome_json(&snap))
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, &path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(id: u64, outcome: TraceOutcome, admit: u64, done: u64) -> RequestTrace {
        RequestTrace {
            id,
            shard: 0,
            variant: "t".to_string(),
            outcome,
            t_admit: admit,
            t_batch: if outcome == TraceOutcome::Shed { 0 } else { admit + 1 },
            t_exec_start: if outcome == TraceOutcome::Delivered { admit + 2 } else { 0 },
            t_exec_end: if outcome == TraceOutcome::Delivered { done - 1 } else { 0 },
            t_done: done,
        }
    }

    #[test]
    fn tail_sampling_keeps_failures_slowest_and_samples() {
        let c = TraceCollector::new();
        // Failures always kept, regardless of latency.
        c.complete(trace(1, TraceOutcome::Shed, 0, 1));
        c.complete(trace(3, TraceOutcome::DeadlineExpired, 0, 5));
        // Untraced id never kept.
        c.complete(trace(0, TraceOutcome::Shed, 0, 1));
        // Fill slowest beyond K with increasing latencies; the floor must
        // evict the fast ones.
        for i in 0..(SLOWEST_K as u64 + 10) {
            // Avoid multiples of SAMPLE_EVERY so the sample ring stays
            // deterministic in this test.
            let id = i * 2 + 1001;
            c.complete(trace(id, TraceOutcome::Delivered, 0, 10 + i * 10));
        }
        // One sampled healthy fast request.
        c.complete(trace(SAMPLE_EVERY * 5, TraceOutcome::Delivered, 0, 3));
        let snap = c.snapshot();
        assert_eq!(snap.failures.len(), 2);
        assert_eq!(snap.slowest.len(), SLOWEST_K);
        // Slowest is sorted descending and holds the top-K latencies.
        assert!(snap.slowest[0].latency_us() >= snap.slowest.last().unwrap().latency_us());
        assert_eq!(snap.slowest[0].latency_us(), 10 + (SLOWEST_K as u64 + 9) * 10);
        assert!(snap.sampled.iter().any(|t| t.id == SAMPLE_EVERY * 5));
        assert_eq!(snap.failures_dropped, 0);

        // take() drains.
        let taken = c.take();
        assert_eq!(taken.failures.len(), 2);
        assert!(c.snapshot().failures.is_empty());
    }

    #[test]
    fn chrome_export_emits_stage_slices_per_trace() {
        let mut snap = TraceSnapshot::default();
        snap.failures.push(trace(9, TraceOutcome::Shed, 100, 100));
        snap.slowest.push(trace(4, TraceOutcome::Delivered, 10, 50));
        let json = to_chrome_json(&snap);
        let doc = crate::obs::json::parse(&json).unwrap();
        let evs = doc
            .get("traceEvents")
            .and_then(crate::obs::json::Json::as_array)
            .unwrap();
        // Shed: queue only. Delivered: queue + execute + respond.
        assert_eq!(evs.len(), 4);
        let names: Vec<&str> = evs
            .iter()
            .filter_map(|e| e.get("name").and_then(crate::obs::json::Json::as_str))
            .collect();
        assert_eq!(names, ["queue", "queue", "execute", "respond"]);
        // Every event carries its trace id + outcome for regrouping.
        for e in evs {
            let args = e.get("args").unwrap();
            assert!(args.get("trace").and_then(crate::obs::json::Json::as_u64).is_some());
            assert!(args.get("outcome").and_then(crate::obs::json::Json::as_str).is_some());
        }
    }

    #[test]
    fn disabled_tracing_yields_untraced_stamps() {
        let was = trace_enabled();
        crate::obs::set_trace_enabled(false);
        let s = StageStamps::begin();
        crate::obs::set_trace_enabled(was);
        assert_eq!(s.id, 0);
        assert_eq!(s.t_admit, 0);
        let mut s2 = s;
        s2.stamp_batch(123);
        s2.stamp_exec(1, 2);
        assert_eq!((s2.t_batch, s2.t_exec_start, s2.t_exec_end), (0, 0, 0));
    }
}
