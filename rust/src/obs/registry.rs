//! The process-wide metrics registry: named counters, gauges and
//! fixed-memory log-bucketed latency histograms.
//!
//! Design constraints (DESIGN.md §Observability):
//!
//! * **Lock-free record path** — handles are `Arc`s over sharded atomics;
//!   after the one-time name lookup, `add`/`set`/`record` never take a
//!   lock. Shards are assigned per thread (round-robin at first touch) so
//!   concurrent recorders don't contend on one cache line.
//! * **Fixed memory** — a histogram is ~252 buckets per shard regardless
//!   of how many values it absorbs: bucket `i` covers a log₂ range split
//!   into 4 sub-buckets (≤ 12.5% relative error at the midpoint), which
//!   is what lets `ServerMetrics` retire its unbounded latency `Vec`.
//! * **Mergeable snapshots** — [`RegistrySnapshot`] supports `merge`
//!   (accumulate across processes: `openacm compile` then `openacm
//!   serve` into one `snapshot.json`) and `diff` (what happened between
//!   two snapshots), both exact for counters and bucket counts.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use super::json::Json;

/// Counter shards: enough to keep a hot 8-worker batcher from bouncing
/// one cache line, small enough that snapshotting stays trivial.
const COUNTER_SHARDS: usize = 8;
/// Histogram shards (each shard is a full bucket array, so keep it low).
const HIST_SHARDS: usize = 4;
/// Sub-bucket resolution: 2 bits = 4 sub-buckets per power of two.
const SUB_BITS: u32 = 2;
const SUBS: usize = 1 << SUB_BITS;
/// Total bucket count for the full `u64` range (see [`bucket_index`]).
pub const HIST_BUCKETS: usize = (64 - SUB_BITS as usize) * SUBS + SUBS;

/// Log-bucket index of a value: values `< 4` map linearly, above that the
/// 2 bits after the leading one select a sub-bucket within the octave.
/// Contiguous and monotone over the whole `u64` range.
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let sub = ((v >> (msb - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    ((msb - SUB_BITS + 1) as usize) * SUBS + sub
}

/// Lowest value that lands in bucket `idx` (inverse of [`bucket_index`]).
pub fn bucket_lo(idx: usize) -> u64 {
    if idx < SUBS {
        return idx as u64;
    }
    let oct = (idx / SUBS) as u32;
    let sub = (idx % SUBS) as u64;
    let msb = oct - 1 + SUB_BITS;
    (1u64 << msb) | (sub << (msb - SUB_BITS))
}

/// Highest value that lands in bucket `idx`.
pub fn bucket_hi(idx: usize) -> u64 {
    if idx + 1 < HIST_BUCKETS {
        bucket_lo(idx + 1) - 1
    } else {
        u64::MAX
    }
}

/// The value a bucket reports for percentiles: its midpoint, which halves
/// the worst-case relative error to ≤ 12.5%.
fn bucket_mid(idx: usize) -> u64 {
    let lo = bucket_lo(idx);
    let hi = bucket_hi(idx);
    lo + (hi - lo) / 2
}

/// Round-robin shard slot for the calling thread, cached in a TLS cell so
/// the record path costs one TLS read (no `ThreadId` hashing).
fn shard_idx(shards: usize) -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    SLOT.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            s.set(v);
        }
        v % shards
    })
}

/// One cache line per shard so concurrent `fetch_add`s don't false-share.
#[repr(align(64))]
#[derive(Debug, Default)]
struct PaddedU64(AtomicU64);

#[derive(Debug)]
struct CounterInner {
    shards: [PaddedU64; COUNTER_SHARDS],
}

/// A monotonically increasing named counter. Cheap to clone (an `Arc`);
/// `add` is one relaxed `fetch_add` on a thread-local shard.
#[derive(Clone, Debug)]
pub struct Counter(Arc<CounterInner>);

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl Counter {
    pub fn new() -> Counter {
        Counter(Arc::new(CounterInner {
            shards: Default::default(),
        }))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.shards[shard_idx(COUNTER_SHARDS)]
            .0
            .fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn value(&self) -> u64 {
        self.0
            .shards
            .iter()
            .map(|s| s.0.load(Ordering::Relaxed))
            .sum()
    }
}

#[derive(Debug, Default)]
struct GaugeInner {
    value: AtomicI64,
}

/// A last-value-wins signed gauge (queue depth, in-flight count, SIMD
/// tier). `add` takes negative deltas for RAII decrement-on-drop.
#[derive(Clone, Debug)]
pub struct Gauge(Arc<GaugeInner>);

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(Arc::new(GaugeInner::default()))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.value.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.0.value.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn value(&self) -> i64 {
        self.0.value.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistShard {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl HistShard {
    fn new() -> HistShard {
        HistShard {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

#[derive(Debug)]
struct HistogramInner {
    shards: Vec<HistShard>,
    /// One exemplar slot per bucket (shared across shards): the trace id
    /// of the most recent value that landed in that bucket, 0 = none.
    /// Last-writer-wins relaxed stores keep the record path lock-free;
    /// fixed memory (`HIST_BUCKETS` atomics) regardless of traffic.
    exemplars: Box<[AtomicU64]>,
}

/// A fixed-memory log-bucketed histogram (typically of microsecond
/// durations). Memory is `HIST_SHARDS × HIST_BUCKETS` atomics forever,
/// independent of how many values are recorded.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistogramInner>);

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram(Arc::new(HistogramInner {
            shards: (0..HIST_SHARDS).map(|_| HistShard::new()).collect(),
            exemplars: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }))
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let s = &self.0.shards[shard_idx(HIST_SHARDS)];
        s.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
        s.min.fetch_min(v, Ordering::Relaxed);
        s.max.fetch_max(v, Ordering::Relaxed);
    }

    /// [`Self::record`], additionally tagging the value's bucket with an
    /// exemplar id (a trace id) so percentile queries can link back to a
    /// concrete request. `exemplar == 0` means "no exemplar" and degrades
    /// to a plain `record`.
    #[inline]
    pub fn record_with_exemplar(&self, v: u64, exemplar: u64) {
        self.record(v);
        if exemplar != 0 {
            self.0.exemplars[bucket_index(v)].store(exemplar, Ordering::Relaxed);
        }
    }

    /// Merge every shard into one immutable view.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets: BTreeMap<u32, u64> = BTreeMap::new();
        let (mut count, mut sum, mut min, mut max) = (0u64, 0u64, u64::MAX, 0u64);
        for s in &self.0.shards {
            count += s.count.load(Ordering::Relaxed);
            sum += s.sum.load(Ordering::Relaxed);
            min = min.min(s.min.load(Ordering::Relaxed));
            max = max.max(s.max.load(Ordering::Relaxed));
            for (i, b) in s.buckets.iter().enumerate() {
                let c = b.load(Ordering::Relaxed);
                if c > 0 {
                    *buckets.entry(i as u32).or_insert(0) += c;
                }
            }
        }
        let exemplars: Vec<(u32, u64)> = self
            .0
            .exemplars
            .iter()
            .enumerate()
            .filter_map(|(i, e)| {
                let id = e.load(Ordering::Relaxed);
                (id != 0).then_some((i as u32, id))
            })
            .collect();
        HistogramSnapshot {
            count,
            sum,
            min: if count == 0 { 0 } else { min },
            max,
            buckets: buckets.into_iter().collect(),
            exemplars,
        }
    }

    /// Bytes held by the bucket arrays — constant by construction; the
    /// serving soak asserts this does not move with request count.
    pub fn resident_bytes(&self) -> usize {
        self.0
            .shards
            .iter()
            .map(|s| s.buckets.len() * std::mem::size_of::<AtomicU64>())
            .sum()
    }
}

/// Immutable, mergeable view of one histogram.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    /// Sparse `(bucket index, count)` pairs, ascending by index.
    pub buckets: Vec<(u32, u64)>,
    /// Sparse `(bucket index, trace id)` exemplars, ascending by index:
    /// the most recent trace that landed in each bucket (0 = never one).
    pub exemplars: Vec<(u32, u64)>,
}

impl HistogramSnapshot {
    /// Approximate percentile (`p` in 0..=100): the midpoint of the bucket
    /// holding the rank, clamped to the observed `[min, max]`. Bucket
    /// geometry bounds the relative error at ≤ 12.5% (see module docs);
    /// `rust/tests/obs.rs` checks it against the exact sorted reference.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for &(idx, c) in &self.buckets {
            cum += c;
            if cum >= rank {
                return bucket_mid(idx as usize).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The exemplar trace id nearest the bucket that holds percentile `p`:
    /// the bucket itself first, then widening to neighbours (higher bucket
    /// preferred on ties — for tail percentiles the slower exemplar is the
    /// interesting one). `None` when no exemplar was ever recorded.
    pub fn exemplar_near_percentile(&self, p: f64) -> Option<u64> {
        if self.count == 0 || self.exemplars.is_empty() {
            return None;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        let mut target = self.buckets.last().map(|&(i, _)| i).unwrap_or(0);
        for &(idx, c) in &self.buckets {
            cum += c;
            if cum >= rank {
                target = idx;
                break;
            }
        }
        self.exemplars
            .iter()
            .min_by_key(|&&(i, _)| {
                let dist = (i64::from(i) - i64::from(target)).unsigned_abs();
                // Prefer the higher bucket on equal distance.
                (dist, i < target)
            })
            .map(|&(_, id)| id)
    }

    /// Accumulate `other` into `self` (exact for counts and bucket
    /// contents — the property that makes cross-process snapshot files
    /// additive).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if other.count == 0 {
            return;
        }
        let had = self.count > 0;
        self.count += other.count;
        self.sum += other.sum;
        self.min = if had { self.min.min(other.min) } else { other.min };
        self.max = self.max.max(other.max);
        let mut map: BTreeMap<u32, u64> = self.buckets.iter().cloned().collect();
        for &(i, c) in &other.buckets {
            *map.entry(i).or_insert(0) += c;
        }
        self.buckets = map.into_iter().collect();
        // Exemplars are last-writer-wins: `other` is the more recent side.
        let mut ex: BTreeMap<u32, u64> = self.exemplars.iter().cloned().collect();
        for &(i, id) in &other.exemplars {
            ex.insert(i, id);
        }
        self.exemplars = ex.into_iter().collect();
    }

    /// What happened after `earlier` (bucket-wise saturating subtraction;
    /// `min`/`max` keep the later snapshot's values, an approximation the
    /// CLI labels as such).
    pub fn diff(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let early: BTreeMap<u32, u64> = earlier.buckets.iter().cloned().collect();
        let buckets: Vec<(u32, u64)> = self
            .buckets
            .iter()
            .filter_map(|&(i, c)| {
                let d = c.saturating_sub(early.get(&i).copied().unwrap_or(0));
                (d > 0).then_some((i, d))
            })
            .collect();
        HistogramSnapshot {
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            min: self.min,
            max: self.max,
            buckets,
            // The later snapshot's exemplars are the freshest examples.
            exemplars: self.exemplars.clone(),
        }
    }
}

#[derive(Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A named-metric registry. One process-wide instance lives behind
/// [`global`]; tests construct private ones.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    metrics: RwLock<HashMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get-or-register a counter. Panics if `name` is already registered
    /// as a different metric kind (a programming error, not a data error).
    pub fn counter(&self, name: &str) -> Counter {
        if let Some(Metric::Counter(c)) = self.metrics.read().unwrap().get(name) {
            return c.clone();
        }
        let mut w = self.metrics.write().unwrap();
        match w
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter::new()))
        {
            Metric::Counter(c) => c.clone(),
            other => panic!("metric {name:?} already registered as {other:?}, wanted counter"),
        }
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        if let Some(Metric::Gauge(g)) = self.metrics.read().unwrap().get(name) {
            return g.clone();
        }
        let mut w = self.metrics.write().unwrap();
        match w
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge::new()))
        {
            Metric::Gauge(g) => g.clone(),
            other => panic!("metric {name:?} already registered as {other:?}, wanted gauge"),
        }
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        if let Some(Metric::Histogram(h)) = self.metrics.read().unwrap().get(name) {
            return h.clone();
        }
        let mut w = self.metrics.write().unwrap();
        match w
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::new()))
        {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric {name:?} already registered as {other:?}, wanted histogram"),
        }
    }

    /// Point-in-time view of every registered metric.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let g = self.metrics.read().unwrap();
        let mut snap = RegistrySnapshot::default();
        for (name, m) in g.iter() {
            match m {
                Metric::Counter(c) => {
                    snap.counters.insert(name.clone(), c.value());
                }
                Metric::Gauge(v) => {
                    snap.gauges.insert(name.clone(), v.value());
                }
                Metric::Histogram(h) => {
                    snap.histograms.insert(name.clone(), h.snapshot());
                }
            }
        }
        snap
    }
}

/// Immutable view of a whole registry; serializes to/from the JSON the
/// `openacm obs` CLI and the on-disk `snapshot.json` use.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl RegistrySnapshot {
    /// Accumulate `other`: counters and histogram buckets add, gauges take
    /// `other`'s (more recent) value.
    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// What happened between `earlier` and `self` (saturating; names only
    /// present in `earlier` are dropped).
    pub fn diff(&self, earlier: &RegistrySnapshot) -> RegistrySnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| {
                let e = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(e))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let e = earlier.histograms.get(k).cloned().unwrap_or_default();
                (k.clone(), h.diff(&e))
            })
            .collect();
        RegistrySnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// True when nothing happened: every counter is zero and every
    /// histogram is empty. Gauges are excluded — they are levels, not
    /// activity, and a diff carries the later snapshot's gauges verbatim.
    /// `openacm obs diff` uses this for its exit code.
    pub fn is_zero(&self) -> bool {
        self.counters.values().all(|&v| v == 0)
            && self.histograms.values().all(|h| h.count == 0)
    }

    /// Hand-rolled JSON (offline build, no serde) — same convention as
    /// [`crate::bench::harness::BenchJson`]. Deterministic: maps are
    /// `BTreeMap`s, so equal snapshots render byte-identically.
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut s = String::from("{\n  \"counters\": {");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            s.push_str(if i == 0 { "\n" } else { ",\n" });
            s.push_str(&format!("    \"{}\": {v}", esc(k)));
        }
        s.push_str("\n  },\n  \"gauges\": {");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            s.push_str(if i == 0 { "\n" } else { ",\n" });
            s.push_str(&format!("    \"{}\": {v}", esc(k)));
        }
        s.push_str("\n  },\n  \"histograms\": {");
        for (i, (k, h)) in self.histograms.iter().enumerate() {
            s.push_str(if i == 0 { "\n" } else { ",\n" });
            let buckets: Vec<String> = h
                .buckets
                .iter()
                .map(|(bi, c)| format!("[{bi},{c}]"))
                .collect();
            let exemplars = if h.exemplars.is_empty() {
                String::new()
            } else {
                let pairs: Vec<String> = h
                    .exemplars
                    .iter()
                    .map(|(bi, id)| format!("[{bi},{id}]"))
                    .collect();
                format!(", \"exemplars\": [{}]", pairs.join(","))
            };
            s.push_str(&format!(
                "    \"{}\": {{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \
                 \"buckets\": [{}]{}}}",
                esc(k),
                h.count,
                h.sum,
                h.min,
                h.max,
                buckets.join(","),
                exemplars
            ));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Parse the format [`Self::to_json`] emits (used by `obs
    /// snapshot|diff` and the cross-process merge in [`super::sink`]).
    pub fn from_json(text: &str) -> anyhow::Result<RegistrySnapshot> {
        let doc = super::json::parse(text)?;
        let mut snap = RegistrySnapshot::default();
        if let Some(obj) = doc.get("counters").and_then(Json::as_object) {
            for (k, v) in obj {
                snap.counters
                    .insert(k.clone(), v.as_u64().unwrap_or_default());
            }
        }
        if let Some(obj) = doc.get("gauges").and_then(Json::as_object) {
            for (k, v) in obj {
                snap.gauges.insert(k.clone(), v.as_i64().unwrap_or_default());
            }
        }
        if let Some(obj) = doc.get("histograms").and_then(Json::as_object) {
            for (k, v) in obj {
                let mut h = HistogramSnapshot {
                    count: v.get("count").and_then(Json::as_u64).unwrap_or_default(),
                    sum: v.get("sum").and_then(Json::as_u64).unwrap_or_default(),
                    min: v.get("min").and_then(Json::as_u64).unwrap_or_default(),
                    max: v.get("max").and_then(Json::as_u64).unwrap_or_default(),
                    buckets: Vec::new(),
                    exemplars: Vec::new(),
                };
                if let Some(arr) = v.get("buckets").and_then(Json::as_array) {
                    for pair in arr {
                        if let Some(p) = pair.as_array() {
                            if p.len() == 2 {
                                h.buckets.push((
                                    p[0].as_u64().unwrap_or_default() as u32,
                                    p[1].as_u64().unwrap_or_default(),
                                ));
                            }
                        }
                    }
                }
                if let Some(arr) = v.get("exemplars").and_then(Json::as_array) {
                    for pair in arr {
                        if let Some(p) = pair.as_array() {
                            if p.len() == 2 {
                                h.exemplars.push((
                                    p[0].as_u64().unwrap_or_default() as u32,
                                    p[1].as_u64().unwrap_or_default(),
                                ));
                            }
                        }
                    }
                }
                snap.histograms.insert(k.clone(), h);
            }
        }
        Ok(snap)
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry every subsystem reports through.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_contiguous_and_monotone() {
        // Exhaustive over the small range, spot checks across octaves.
        let mut prev = 0usize;
        for v in 0u64..4096 {
            let idx = bucket_index(v);
            assert!(idx == prev || idx == prev + 1, "gap at v={v}");
            assert!(bucket_lo(idx) <= v && v <= bucket_hi(idx), "bounds at v={v}");
            prev = idx;
        }
        for shift in 2..63 {
            let v = 1u64 << shift;
            let idx = bucket_index(v);
            assert_eq!(bucket_lo(idx), v);
            assert!(bucket_index(v - 1) == idx - 1);
        }
        assert!(bucket_index(u64::MAX) < HIST_BUCKETS);
    }

    #[test]
    fn counter_gauge_histogram_roundtrip() {
        let r = MetricsRegistry::new();
        r.counter("a").add(3);
        r.counter("a").add(4);
        r.gauge("g").set(7);
        r.gauge("g").add(-2);
        let h = r.histogram("h");
        for v in [1u64, 10, 100, 1000] {
            h.record(v);
        }
        let s = r.snapshot();
        assert_eq!(s.counters["a"], 7);
        assert_eq!(s.gauges["g"], 5);
        let hs = &s.histograms["h"];
        assert_eq!(hs.count, 4);
        assert_eq!(hs.sum, 1111);
        assert_eq!((hs.min, hs.max), (1, 1000));
    }

    #[test]
    fn snapshot_merge_and_diff_are_inverse_for_counters() {
        let r = MetricsRegistry::new();
        r.counter("x").add(10);
        r.histogram("h").record(500);
        let a = r.snapshot();
        r.counter("x").add(5);
        r.histogram("h").record(700);
        let b = r.snapshot();
        let d = b.diff(&a);
        assert_eq!(d.counters["x"], 5);
        assert_eq!(d.histograms["h"].count, 1);
        assert!(!d.is_zero());
        assert!(b.diff(&b).is_zero(), "self-diff is empty");
        let mut merged = a.clone();
        merged.merge(&d);
        assert_eq!(merged.counters["x"], b.counters["x"]);
        assert_eq!(merged.histograms["h"].count, b.histograms["h"].count);
    }

    #[test]
    fn exemplars_tag_buckets_and_survive_json_and_merge() {
        let r = MetricsRegistry::new();
        let h = r.histogram("serve.latency_us");
        h.record_with_exemplar(10, 0); // id 0 = no exemplar
        h.record_with_exemplar(10, 7);
        h.record_with_exemplar(100_000, 42);
        let s = r.snapshot();
        let hs = &s.histograms["serve.latency_us"];
        assert_eq!(hs.exemplars.len(), 2);
        assert_eq!(hs.exemplar_near_percentile(99.0), Some(42));
        assert_eq!(hs.exemplar_near_percentile(1.0), Some(7));
        // Round-trips through the snapshot JSON.
        let back = RegistrySnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Merge: the other (more recent) side's exemplar wins per bucket.
        let mut a = hs.clone();
        let mut b = hs.clone();
        b.exemplars = vec![(bucket_index(10) as u32, 9)];
        a.merge(&b);
        let map: std::collections::BTreeMap<u32, u64> = a.exemplars.into_iter().collect();
        assert_eq!(map[&(bucket_index(10) as u32)], 9);
        assert_eq!(map[&(bucket_index(100_000) as u32)], 42);
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let r = MetricsRegistry::new();
        r.counter("serve.completed").add(42);
        r.gauge("simd.level").set(1);
        let h = r.histogram("serve.latency_us");
        for v in [12u64, 90, 90, 4000] {
            h.record(v);
        }
        let snap = r.snapshot();
        let back = RegistrySnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
    }
}
