//! Structured JSONL event log: severity, timestamp, subsystem and
//! `key=value` fields, absorbing what used to be bare `eprintln!`s.
//!
//! Every event lands in a bounded in-memory ring (for `openacm obs tail`
//! inside the emitting process and for tests) and, when a sink file is
//! attached ([`attach_file`], done by `openacm serve` / `openacm
//! compile` via [`super::sink::init`]), is appended as one JSON line.
//! Warn/Error events mirror to stderr by default so pre-existing behavior
//! — backend warnings and execute failures being visible on the console —
//! is unchanged.

use std::collections::VecDeque;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Debug,
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Debug => "debug",
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// One structured event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Milliseconds since the Unix epoch.
    pub ts_ms: u64,
    pub severity: Severity,
    pub subsystem: String,
    pub message: String,
    pub fields: Vec<(String, String)>,
}

impl Event {
    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut s = format!(
            "{{\"ts_ms\": {}, \"severity\": \"{}\", \"subsystem\": \"{}\", \"message\": \"{}\"",
            self.ts_ms,
            self.severity.name(),
            esc(&self.subsystem),
            esc(&self.message)
        );
        if !self.fields.is_empty() {
            s.push_str(", \"fields\": {");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": \"{}\"", esc(k), esc(v)));
            }
            s.push('}');
        }
        s.push('}');
        s
    }

    /// The stderr mirror line. Warnings keep the historical `WARNING: …`
    /// prefix (`runtime::backend` used to print exactly that).
    fn mirror_line(&self) -> String {
        let fields: String = self
            .fields
            .iter()
            .map(|(k, v)| format!(" {k}={v}"))
            .collect();
        match self.severity {
            Severity::Warn => format!("WARNING: {}{fields}", self.message),
            Severity::Error => format!("ERROR ({}): {}{fields}", self.subsystem, self.message),
            _ => format!("[{}] {}{fields}", self.subsystem, self.message),
        }
    }
}

/// Ring capacity: bounded, like every other obs structure.
const RING_CAP: usize = 1024;

/// Default rotation threshold for the JSONL sink file. Long soaks used to
/// grow `events.jsonl` without limit even though the in-memory ring is
/// bounded; past the cap the file rotates to `events.jsonl.1` (one
/// generation kept) and a fresh file starts. Override with
/// `OPENACM_OBS_EVENTS_MAX_BYTES` or [`set_rotate_cap`].
const DEFAULT_ROTATE_BYTES: u64 = 8 * 1024 * 1024;

struct LogState {
    ring: VecDeque<Event>,
    file: Option<std::fs::File>,
    /// Path of the attached sink (needed to rotate it).
    path: Option<PathBuf>,
    /// Bytes written to the current sink file (including pre-existing
    /// content found at attach time).
    written: u64,
    rotate_cap: u64,
    mirror_stderr: bool,
}

fn log_state() -> &'static Mutex<LogState> {
    static LOG: OnceLock<Mutex<LogState>> = OnceLock::new();
    LOG.get_or_init(|| {
        let cap = std::env::var("OPENACM_OBS_EVENTS_MAX_BYTES")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .filter(|&v| v > 0)
            .unwrap_or(DEFAULT_ROTATE_BYTES);
        Mutex::new(LogState {
            ring: VecDeque::with_capacity(RING_CAP),
            file: None,
            path: None,
            written: 0,
            rotate_cap: cap,
            mirror_stderr: true,
        })
    })
}

/// Rotate `<path>` to `<path>.1` (replacing any prior generation) and
/// reopen a fresh sink. On any filesystem error the sink degrades to the
/// in-memory ring only — telemetry must never take the process down.
fn rotate(g: &mut LogState) {
    let Some(path) = g.path.clone() else { return };
    g.file = None; // close before renaming so the handle can't follow the old inode
    let rotated = {
        let mut os = path.clone().into_os_string();
        os.push(".1");
        PathBuf::from(os)
    };
    let _ = std::fs::rename(&path, &rotated);
    match OpenOptions::new().create(true).append(true).open(&path) {
        Ok(f) => {
            g.written = f.metadata().map(|m| m.len()).unwrap_or(0);
            g.file = Some(f);
        }
        Err(_) => {
            g.path = None;
            g.written = 0;
        }
    }
}

/// Emit one event. `fields` are `(key, value)` pairs; values are already
/// rendered (events are off the hot path — this allocates freely).
pub fn emit(severity: Severity, subsystem: &str, message: &str, fields: &[(&str, String)]) {
    let ev = Event {
        ts_ms: SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0),
        severity,
        subsystem: subsystem.to_string(),
        message: message.to_string(),
        fields: fields
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    };
    let mut g = log_state().lock().unwrap();
    if let Some(f) = g.file.as_mut() {
        // Sink write failures must never take the serving path down;
        // drop the sink and keep the ring + mirror.
        let line = ev.to_jsonl();
        if writeln!(f, "{line}").is_err() {
            g.file = None;
        } else {
            g.written += line.len() as u64 + 1;
            if g.written > g.rotate_cap {
                rotate(&mut g);
            }
        }
    }
    if g.mirror_stderr && severity >= Severity::Warn {
        eprintln!("{}", ev.mirror_line());
    }
    if g.ring.len() == RING_CAP {
        g.ring.pop_front();
    }
    g.ring.push_back(ev);
}

pub fn info(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Info, subsystem, message, fields);
}

pub fn warn(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Warn, subsystem, message, fields);
}

pub fn error(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Error, subsystem, message, fields);
}

/// Append events to `path` (JSONL) from now on. Pre-existing file size
/// counts toward the rotation cap, so re-attaching to a large old log
/// rotates on the first overflowing event rather than doubling it.
pub fn attach_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    let written = f.metadata().map(|m| m.len()).unwrap_or(0);
    let mut g = log_state().lock().unwrap();
    g.file = Some(f);
    g.path = Some(path.to_path_buf());
    g.written = written;
    Ok(())
}

/// Toggle the Warn/Error stderr mirror (default on).
pub fn set_stderr_mirror(on: bool) {
    log_state().lock().unwrap().mirror_stderr = on;
}

/// Override the JSONL sink rotation threshold in bytes (tests; long
/// soaks with tight disk budgets). Values ≤ 0 are ignored.
pub fn set_rotate_cap(bytes: u64) {
    if bytes > 0 {
        log_state().lock().unwrap().rotate_cap = bytes;
    }
}

/// The most recent `n` events (oldest first).
pub fn recent(n: usize) -> Vec<Event> {
    let g = log_state().lock().unwrap();
    g.ring.iter().rev().take(n).rev().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_ring_and_render_as_parseable_jsonl() {
        set_stderr_mirror(false);
        emit(
            Severity::Warn,
            "obs_test",
            "weights look \"odd\"",
            &[("variant", "exact".to_string()), ("n", "3".to_string())],
        );
        set_stderr_mirror(true);
        let evs = recent(RING_CAP);
        let ev = evs
            .iter()
            .rev()
            .find(|e| e.subsystem == "obs_test")
            .expect("event in ring");
        assert_eq!(ev.severity, Severity::Warn);
        let line = ev.to_jsonl();
        let doc = super::super::json::parse(&line).unwrap();
        assert_eq!(doc.get("severity").unwrap().as_str(), Some("warn"));
        assert_eq!(doc.get("message").unwrap().as_str(), Some("weights look \"odd\""));
        assert_eq!(
            doc.get("fields").unwrap().get("variant").unwrap().as_str(),
            Some("exact")
        );
    }

    #[test]
    fn sink_file_rotates_at_size_cap() {
        let dir = std::env::temp_dir().join(format!("openacm-obs-rot-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        set_stderr_mirror(false);
        attach_file(&path).unwrap();
        set_rotate_cap(512);
        for i in 0..64 {
            info(
                "obs_rotate_test",
                "filler event to overflow the sink",
                &[("i", i.to_string())],
            );
        }
        set_rotate_cap(DEFAULT_ROTATE_BYTES);
        set_stderr_mirror(true);
        let rotated = dir.join("events.jsonl.1");
        assert!(rotated.exists(), "rotated generation exists");
        let cur_len = std::fs::metadata(&path).unwrap().len();
        // Current file restarts after each rotation, so it stays within
        // one event line of the cap.
        assert!(cur_len <= 512 + 256, "current file near cap, got {cur_len}");
        let text = std::fs::read_to_string(&rotated).unwrap();
        assert!(!text.is_empty());
        assert!(text.lines().all(|l| super::super::json::parse(l).is_ok()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
