//! Structured JSONL event log: severity, timestamp, subsystem and
//! `key=value` fields, absorbing what used to be bare `eprintln!`s.
//!
//! Every event lands in a bounded in-memory ring (for `openacm obs tail`
//! inside the emitting process and for tests) and, when a sink file is
//! attached ([`attach_file`], done by `openacm serve` / `openacm
//! compile` via [`super::sink::init`]), is appended as one JSON line.
//! Warn/Error events mirror to stderr by default so pre-existing behavior
//! — backend warnings and execute failures being visible on the console —
//! is unchanged.

use std::collections::VecDeque;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Debug,
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Debug => "debug",
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// One structured event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Milliseconds since the Unix epoch.
    pub ts_ms: u64,
    pub severity: Severity,
    pub subsystem: String,
    pub message: String,
    pub fields: Vec<(String, String)>,
}

impl Event {
    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len());
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let mut s = format!(
            "{{\"ts_ms\": {}, \"severity\": \"{}\", \"subsystem\": \"{}\", \"message\": \"{}\"",
            self.ts_ms,
            self.severity.name(),
            esc(&self.subsystem),
            esc(&self.message)
        );
        if !self.fields.is_empty() {
            s.push_str(", \"fields\": {");
            for (i, (k, v)) in self.fields.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("\"{}\": \"{}\"", esc(k), esc(v)));
            }
            s.push('}');
        }
        s.push('}');
        s
    }

    /// The stderr mirror line. Warnings keep the historical `WARNING: …`
    /// prefix (`runtime::backend` used to print exactly that).
    fn mirror_line(&self) -> String {
        let fields: String = self
            .fields
            .iter()
            .map(|(k, v)| format!(" {k}={v}"))
            .collect();
        match self.severity {
            Severity::Warn => format!("WARNING: {}{fields}", self.message),
            Severity::Error => format!("ERROR ({}): {}{fields}", self.subsystem, self.message),
            _ => format!("[{}] {}{fields}", self.subsystem, self.message),
        }
    }
}

/// Ring capacity: bounded, like every other obs structure.
const RING_CAP: usize = 1024;

struct LogState {
    ring: VecDeque<Event>,
    file: Option<std::fs::File>,
    mirror_stderr: bool,
}

fn log_state() -> &'static Mutex<LogState> {
    static LOG: OnceLock<Mutex<LogState>> = OnceLock::new();
    LOG.get_or_init(|| {
        Mutex::new(LogState {
            ring: VecDeque::with_capacity(RING_CAP),
            file: None,
            mirror_stderr: true,
        })
    })
}

/// Emit one event. `fields` are `(key, value)` pairs; values are already
/// rendered (events are off the hot path — this allocates freely).
pub fn emit(severity: Severity, subsystem: &str, message: &str, fields: &[(&str, String)]) {
    let ev = Event {
        ts_ms: SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0),
        severity,
        subsystem: subsystem.to_string(),
        message: message.to_string(),
        fields: fields
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    };
    let mut g = log_state().lock().unwrap();
    if let Some(f) = g.file.as_mut() {
        // Sink write failures must never take the serving path down;
        // drop the sink and keep the ring + mirror.
        if writeln!(f, "{}", ev.to_jsonl()).is_err() {
            g.file = None;
        }
    }
    if g.mirror_stderr && severity >= Severity::Warn {
        eprintln!("{}", ev.mirror_line());
    }
    if g.ring.len() == RING_CAP {
        g.ring.pop_front();
    }
    g.ring.push_back(ev);
}

pub fn info(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Info, subsystem, message, fields);
}

pub fn warn(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Warn, subsystem, message, fields);
}

pub fn error(subsystem: &str, message: &str, fields: &[(&str, String)]) {
    emit(Severity::Error, subsystem, message, fields);
}

/// Append events to `path` (JSONL) from now on.
pub fn attach_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    log_state().lock().unwrap().file = Some(f);
    Ok(())
}

/// Toggle the Warn/Error stderr mirror (default on).
pub fn set_stderr_mirror(on: bool) {
    log_state().lock().unwrap().mirror_stderr = on;
}

/// The most recent `n` events (oldest first).
pub fn recent(n: usize) -> Vec<Event> {
    let g = log_state().lock().unwrap();
    g.ring.iter().rev().take(n).rev().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_ring_and_render_as_parseable_jsonl() {
        set_stderr_mirror(false);
        emit(
            Severity::Warn,
            "obs_test",
            "weights look \"odd\"",
            &[("variant", "exact".to_string()), ("n", "3".to_string())],
        );
        set_stderr_mirror(true);
        let evs = recent(RING_CAP);
        let ev = evs
            .iter()
            .rev()
            .find(|e| e.subsystem == "obs_test")
            .expect("event in ring");
        assert_eq!(ev.severity, Severity::Warn);
        let line = ev.to_jsonl();
        let doc = super::super::json::parse(&line).unwrap();
        assert_eq!(doc.get("severity").unwrap().as_str(), Some("warn"));
        assert_eq!(doc.get("message").unwrap().as_str(), Some("weights look \"odd\""));
        assert_eq!(
            doc.get("fields").unwrap().get("variant").unwrap().as_str(),
            Some("exact")
        );
    }
}
