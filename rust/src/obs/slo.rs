//! SLO burn-rate engine: sliding-window objectives evaluated over a
//! fast/slow window pair (Google-SRE-style multi-window burn alerting).
//!
//! Three serving objectives, each a bad/total ratio over a window of
//! evaluation ticks:
//!
//! * **availability** — user-visible errors (failed deliveries + sheds)
//!   over all settled requests; the issue's `delivered/admitted` framing,
//!   widened to count overload rejections as unavailability;
//! * **latency** — deliveries that landed past their per-request deadline
//!   (`serve.delivered_late`, i.e. the `--slo-ms` objective) over all
//!   deliveries;
//! * **routing** — accuracy-class requests that fell back to the exact
//!   variant over all class-routed requests.
//!
//! **Burn rate** = error ratio ÷ error budget: burn 1.0 spends exactly
//! the allowed budget, burn 10 exhausts it 10× too fast. A state flips
//! only when *both* windows agree — the fast window gives reaction time,
//! the slow window filters blips (the classic page/ticket pairing):
//! `Error` when fast ∧ slow ≥ `error_burn`, `Warn` when fast ∧ slow ≥
//! `warn_burn`. Transitions emit typed warn/error events; the current
//! burn/state surface as `serve.slo.*` gauges, the per-interval `[slo]`
//! line during `openacm serve`, and `openacm obs health --json`.
//!
//! The engine itself is pure (feed [`SloInput`]s, read
//! [`ObjectiveHealth`]s) so the warn→error flip is property-testable
//! without a pipeline; [`SloEngine::tick_and_publish`] is the wired-up
//! form `cmd_serve` drives once per metrics interval.

use std::collections::VecDeque;

/// Cumulative pipeline totals at one evaluation instant (monotone
/// counters, not deltas — the engine differences them per window).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloInput {
    pub delivered: u64,
    /// Admitted but failed (deadline expired, execute failure, panic).
    pub failed: u64,
    /// Rejected at admission / full queues.
    pub shed: u64,
    /// Delivered, but past the request's deadline.
    pub delivered_late: u64,
    /// Accuracy-class routed requests, and how many fell back to exact.
    pub class_requests: u64,
    pub class_fallbacks: u64,
}

/// Objectives, budgets and window geometry.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Allowed (failed + shed) / settled ratio, e.g. 0.01 = 99% available.
    pub availability_budget: f64,
    /// Allowed late-delivery ratio against the `--slo-ms` deadline.
    pub latency_budget: f64,
    /// Allowed class-fallback ratio (fallbacks cost energy, not errors,
    /// so the budget is looser).
    pub routing_budget: f64,
    /// Window lengths in evaluation ticks (a tick = one `--metrics-every`
    /// interval in `openacm serve`).
    pub fast_window: usize,
    pub slow_window: usize,
    /// Burn thresholds: ≥ `warn_burn` in both windows ⇒ Warn, ≥
    /// `error_burn` in both ⇒ Error.
    pub warn_burn: f64,
    pub error_burn: f64,
}

impl Default for SloPolicy {
    fn default() -> SloPolicy {
        SloPolicy {
            availability_budget: 0.01,
            latency_budget: 0.01,
            routing_budget: 0.05,
            fast_window: 3,
            slow_window: 12,
            warn_burn: 1.0,
            error_burn: 10.0,
        }
    }
}

/// Health state of one objective, ordered by severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloState {
    Ok,
    Warn,
    Error,
}

impl SloState {
    pub fn name(self) -> &'static str {
        match self {
            SloState::Ok => "ok",
            SloState::Warn => "warn",
            SloState::Error => "error",
        }
    }

    /// Gauge encoding (0/1/2) used for `serve.slo.<objective>.state`.
    pub fn code(self) -> i64 {
        match self {
            SloState::Ok => 0,
            SloState::Warn => 1,
            SloState::Error => 2,
        }
    }
}

/// One objective's evaluation at a tick.
#[derive(Clone, Copy, Debug)]
pub struct ObjectiveHealth {
    pub objective: &'static str,
    pub burn_fast: f64,
    pub burn_slow: f64,
    pub state: SloState,
}

const OBJECTIVES: usize = 3;

/// The burn-rate engine. Feed it cumulative [`SloInput`]s once per tick.
pub struct SloEngine {
    policy: SloPolicy,
    /// Cumulative inputs, oldest first; bounded at `slow_window + 1`.
    history: VecDeque<SloInput>,
    last_states: [SloState; OBJECTIVES],
}

impl SloEngine {
    pub fn new(policy: SloPolicy) -> SloEngine {
        SloEngine {
            policy,
            history: VecDeque::new(),
            last_states: [SloState::Ok; OBJECTIVES],
        }
    }

    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// Bad/total ratio over the last `window` ticks (differencing the
    /// cumulative inputs); 0 when nothing happened in the window.
    fn window_ratio(
        &self,
        window: usize,
        bad: impl Fn(&SloInput) -> u64,
        total: impl Fn(&SloInput) -> u64,
    ) -> f64 {
        let Some(newest) = self.history.back() else {
            return 0.0;
        };
        let base_idx = self.history.len().saturating_sub(window + 1);
        let base = &self.history[base_idx];
        let d_total = total(newest).saturating_sub(total(base));
        if d_total == 0 {
            return 0.0;
        }
        let d_bad = bad(newest).saturating_sub(bad(base));
        d_bad as f64 / d_total as f64
    }

    fn evaluate(
        &self,
        objective: &'static str,
        budget: f64,
        bad: impl Fn(&SloInput) -> u64 + Copy,
        total: impl Fn(&SloInput) -> u64 + Copy,
    ) -> ObjectiveHealth {
        let burn_of = |ratio: f64| if budget > 0.0 { ratio / budget } else { 0.0 };
        let burn_fast = burn_of(self.window_ratio(self.policy.fast_window, bad, total));
        let burn_slow = burn_of(self.window_ratio(self.policy.slow_window, bad, total));
        let both_over = |t: f64| burn_fast >= t && burn_slow >= t;
        let state = if both_over(self.policy.error_burn) {
            SloState::Error
        } else if both_over(self.policy.warn_burn) {
            SloState::Warn
        } else {
            SloState::Ok
        };
        ObjectiveHealth {
            objective,
            burn_fast,
            burn_slow,
            state,
        }
    }

    /// Absorb one cumulative input and evaluate every objective. Pure:
    /// no gauges, no events (see [`Self::tick_and_publish`]).
    pub fn tick(&mut self, input: SloInput) -> Vec<ObjectiveHealth> {
        self.history.push_back(input);
        while self.history.len() > self.policy.slow_window + 1 {
            self.history.pop_front();
        }
        let settled = |i: &SloInput| i.delivered + i.failed + i.shed;
        vec![
            self.evaluate(
                "availability",
                self.policy.availability_budget,
                |i| i.failed + i.shed,
                settled,
            ),
            self.evaluate(
                "latency",
                self.policy.latency_budget,
                |i| i.delivered_late,
                |i| i.delivered,
            ),
            self.evaluate(
                "routing",
                self.policy.routing_budget,
                |i| i.class_fallbacks,
                |i| i.class_requests,
            ),
        ]
    }

    /// [`Self::tick`], then publish: `serve.slo.<objective>.burn_milli` /
    /// `.state` gauges, the aggregate `serve.slo.burn_rate` gauge (max
    /// fast burn × 1000), and typed warn/error events on each state
    /// transition (recovery logs at info).
    pub fn tick_and_publish(&mut self, input: SloInput) -> Vec<ObjectiveHealth> {
        let healths = self.tick(input);
        let mut max_burn_milli = 0i64;
        for (idx, h) in healths.iter().enumerate() {
            let milli = (h.burn_fast * 1000.0).round() as i64;
            max_burn_milli = max_burn_milli.max(milli);
            super::gauge(&format!("serve.slo.{}.burn_milli", h.objective)).set(milli);
            super::gauge(&format!("serve.slo.{}.state", h.objective)).set(h.state.code());
            let prev = self.last_states[idx];
            if h.state != prev {
                let fields = [
                    ("objective", h.objective.to_string()),
                    ("burn_fast", format!("{:.2}", h.burn_fast)),
                    ("burn_slow", format!("{:.2}", h.burn_slow)),
                    ("from", prev.name().to_string()),
                    ("to", h.state.name().to_string()),
                ];
                match h.state {
                    SloState::Error => super::error("slo", "SLO burn critical", &fields),
                    SloState::Warn => super::warn("slo", "SLO burn elevated", &fields),
                    SloState::Ok => super::info("slo", "SLO recovered", &fields),
                }
                self.last_states[idx] = h.state;
            }
        }
        super::gauge("serve.slo.burn_rate").set(max_burn_milli);
        healths
    }
}

/// One-line health summary for the `openacm serve` console, e.g.
/// `[slo] availability 0.0x ok | latency 2.3x warn | routing 0.0x ok`.
pub fn summary_line(healths: &[ObjectiveHealth]) -> String {
    let parts: Vec<String> = healths
        .iter()
        .map(|h| format!("{} {:.1}x {}", h.objective, h.burn_fast, h.state.name()))
        .collect();
    format!("[slo] {}", parts.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A steady stream of ticks: `per_tick` requests settle each tick,
    /// `bad_frac` of them failing.
    fn feed(eng: &mut SloEngine, last: &mut SloInput, per_tick: u64, bad_frac: f64) -> SloState {
        let bad = (per_tick as f64 * bad_frac).round() as u64;
        last.failed += bad;
        last.delivered += per_tick - bad;
        let healths = eng.tick(*last);
        healths[0].state
    }

    #[test]
    fn burn_states_flip_warn_then_error_under_injected_overload() {
        // Budget 1%, warn at burn 1, error at burn 10, windows 3/9 ticks.
        let policy = SloPolicy {
            availability_budget: 0.01,
            fast_window: 3,
            slow_window: 9,
            warn_burn: 1.0,
            error_burn: 10.0,
            ..SloPolicy::default()
        };
        let mut eng = SloEngine::new(policy);
        let mut cum = SloInput::default();

        // Healthy traffic: state stays Ok through both windows.
        for _ in 0..12 {
            assert_eq!(feed(&mut eng, &mut cum, 1000, 0.0), SloState::Ok);
        }

        // Injected overload: 20% failures = burn 20 per overloaded tick.
        // The fast window saturates quickly (reaction), the slow window
        // lags (confirmation) — so the state must pass through Warn
        // before reaching Error, and reach Error while overload persists.
        let mut states = Vec::new();
        for _ in 0..9 {
            states.push(feed(&mut eng, &mut cum, 1000, 0.2));
        }
        let first_warn = states.iter().position(|&s| s >= SloState::Warn);
        let first_error = states.iter().position(|&s| s == SloState::Error);
        assert!(first_warn.is_some(), "overload must raise Warn, got {states:?}");
        assert!(first_error.is_some(), "overload must raise Error, got {states:?}");
        assert!(
            first_warn.unwrap() < first_error.unwrap(),
            "Warn must precede Error: {states:?}"
        );
        assert!(
            states[first_warn.unwrap()] == SloState::Warn,
            "first elevated state is Warn, not an instant Error jump: {states:?}"
        );

        // Recovery: healthy ticks flush both windows back to Ok.
        let mut recovered = SloState::Error;
        for _ in 0..12 {
            recovered = feed(&mut eng, &mut cum, 1000, 0.0);
        }
        assert_eq!(recovered, SloState::Ok);
    }

    #[test]
    fn latency_and_routing_objectives_use_their_own_denominators() {
        let mut eng = SloEngine::new(SloPolicy {
            fast_window: 1,
            slow_window: 2,
            ..SloPolicy::default()
        });
        eng.tick(SloInput::default());
        let healths = eng.tick(SloInput {
            delivered: 100,
            delivered_late: 50, // 50% late / 1% budget = burn 50
            class_requests: 10,
            class_fallbacks: 1, // 10% fallback / 5% budget = burn 2
            ..SloInput::default()
        });
        let lat = healths.iter().find(|h| h.objective == "latency").unwrap();
        assert_eq!(lat.state, SloState::Error);
        assert!((lat.burn_fast - 50.0).abs() < 1e-9);
        let routing = healths.iter().find(|h| h.objective == "routing").unwrap();
        assert_eq!(routing.state, SloState::Warn);
        assert!((routing.burn_fast - 2.0).abs() < 1e-9);
        // No traffic at all ⇒ burn 0, Ok.
        let avail_only = SloEngine::new(SloPolicy::default()).tick(SloInput::default());
        assert!(avail_only.iter().all(|h| h.state == SloState::Ok));
    }

    #[test]
    fn summary_line_mentions_every_objective() {
        let mut eng = SloEngine::new(SloPolicy::default());
        let line = summary_line(&eng.tick(SloInput::default()));
        for name in ["availability", "latency", "routing"] {
            assert!(line.contains(name), "{line} missing {name}");
        }
        assert!(line.starts_with("[slo] "));
    }
}
