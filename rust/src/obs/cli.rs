//! `openacm obs` — inspect the telemetry sink.
//!
//! * `openacm obs snapshot [--dir D] [--json]` — the merged metrics
//!   snapshot accumulated by `openacm serve` / `openacm compile`;
//! * `openacm obs tail [--dir D] [--n K] [--json] [--follow
//!   [--interval-ms MS] [--max-polls K]]` — last K structured events
//!   from `<dir>/events.jsonl`, optionally following appends (and
//!   surviving rotation) like `tail -f`;
//! * `openacm obs diff A.json B.json [--json]` — what happened between
//!   two snapshot files (counters/histograms subtract, gauges read from
//!   the later file); **exits 1 when the diff is non-empty**, so scripts
//!   can assert "this command produced no telemetry";
//! * `openacm obs trace [--dir D] [--slowest N] [--failed] [--json]` —
//!   per-request stage timelines from `<dir>/trace.json` (written by
//!   `openacm serve`; Chrome trace-event format, loadable in
//!   `chrome://tracing`), slowest first;
//! * `openacm obs health [--dir D] [--json]` — SLO burn-rate states from
//!   the accumulated snapshot plus the p99 latency exemplar trace;
//!   exits 2 while any objective is in the error state;
//! * `openacm obs regress --baseline DIR [--current DIR] [--tolerance
//!   PCT] [--times] [--json]` — perf-regression gate over `BENCH_*.json`
//!   emissions ([`super::regress`]); exits 1 on any regression.

use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

use super::registry::RegistrySnapshot;
use super::{json, regress, sink};
use crate::bench::harness::Table;
use crate::util::cli::Args;

pub fn cmd_obs(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(sink::default_dir);
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("snapshot");
    match action {
        "snapshot" => {
            let path = dir.join("snapshot.json");
            let snap = sink::load(&path).with_context(|| {
                format!(
                    "no snapshot at {} — run `openacm serve` or `openacm compile` first",
                    path.display()
                )
            })?;
            if args.flag("json") {
                print!("{}", snap.to_json());
            } else {
                println!("telemetry snapshot {}", path.display());
                print_snapshot(&snap);
            }
            Ok(())
        }
        "tail" => {
            let n = args.usize_or("n", 20)?;
            cmd_tail(&dir, n, args)
        }
        "diff" => {
            let (Some(a), Some(b)) = (args.positional.get(1), args.positional.get(2)) else {
                bail!("usage: openacm obs diff EARLIER.json LATER.json");
            };
            let earlier = sink::load(&PathBuf::from(a))?;
            let later = sink::load(&PathBuf::from(b))?;
            let d = later.diff(&earlier);
            if args.flag("json") {
                print!("{}", d.to_json());
            } else {
                println!("telemetry diff: {a} -> {b} (gauges show the later snapshot)");
                print_snapshot(&d);
            }
            // Scriptable: a non-empty diff (any counter or histogram
            // movement) exits non-zero, like `diff(1)`.
            if !d.is_zero() {
                exit_flushed(1);
            }
            Ok(())
        }
        "trace" => cmd_trace(&dir, args),
        "health" => cmd_health(&dir, args),
        "regress" => cmd_regress(args),
        other => bail!("unknown obs action {other:?}; expected snapshot|tail|diff|trace|health|regress"),
    }
}

/// Flush stdout, then exit. `process::exit` skips buffered-writer
/// destructors; without the flush a piped stdout can lose the report the
/// exit code refers to.
fn exit_flushed(code: i32) -> ! {
    let _ = std::io::stdout().flush();
    std::process::exit(code);
}

/// Human rendering shared by `snapshot` and `diff`.
pub fn print_snapshot(snap: &RegistrySnapshot) {
    if !snap.counters.is_empty() {
        let mut t = Table::new("counters", &["Name", "Value"]);
        for (k, v) in &snap.counters {
            t.row(&[k.clone(), v.to_string()]);
        }
        t.print();
    }
    if !snap.gauges.is_empty() {
        let mut t = Table::new("gauges", &["Name", "Value"]);
        for (k, v) in &snap.gauges {
            t.row(&[k.clone(), v.to_string()]);
        }
        t.print();
    }
    if !snap.histograms.is_empty() {
        let mut t = Table::new(
            "histograms (log-bucketed, percentiles approximate)",
            &["Name", "Count", "Mean", "P50", "P90", "P99", "Max"],
        );
        for (k, h) in &snap.histograms {
            t.row(&[
                k.clone(),
                h.count.to_string(),
                format!("{:.1}", h.mean()),
                h.percentile(50.0).to_string(),
                h.percentile(90.0).to_string(),
                h.percentile(99.0).to_string(),
                h.max.to_string(),
            ]);
        }
        t.print();
    }
    if snap.counters.is_empty() && snap.gauges.is_empty() && snap.histograms.is_empty() {
        println!("(empty)");
    }
}

/// Render one JSONL event line for the console (`--json` passes it raw).
fn print_event_line(line: &str, raw: bool) {
    if raw {
        println!("{line}");
        return;
    }
    match json::parse(line) {
        Ok(doc) => {
            let ts = doc.get("ts_ms").and_then(json::Json::as_u64).unwrap_or(0);
            let sev = doc
                .get("severity")
                .and_then(json::Json::as_str)
                .unwrap_or("?");
            let sub = doc
                .get("subsystem")
                .and_then(json::Json::as_str)
                .unwrap_or("?");
            let msg = doc
                .get("message")
                .and_then(json::Json::as_str)
                .unwrap_or("");
            let fields = doc
                .get("fields")
                .and_then(json::Json::as_object)
                .map(|pairs| {
                    pairs
                        .iter()
                        .map(|(k, v)| format!(" {k}={}", v.as_str().unwrap_or_default()))
                        .collect::<String>()
                })
                .unwrap_or_default();
            println!("{ts} {sev:5} [{sub}] {msg}{fields}");
        }
        // A torn/foreign line should not hide the rest of the tail.
        Err(_) => println!("{line}"),
    }
}

fn cmd_tail(dir: &Path, n: usize, args: &Args) -> Result<()> {
    let path = dir.join("events.jsonl");
    let raw = args.flag("json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no event log at {}", path.display()))?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let start = lines.len().saturating_sub(n);
    for line in &lines[start..] {
        print_event_line(line, raw);
    }
    if args.flag("follow") {
        let interval = Duration::from_millis(args.u64_or("interval-ms", 500)?);
        let max_polls = match args.get("max-polls") {
            Some(_) => Some(args.usize_or("max-polls", 0)?),
            None => None,
        };
        follow_jsonl(&path, interval, max_polls, &mut |line| {
            print_event_line(line, raw)
        })?;
    }
    Ok(())
}

/// Follow appends to a JSONL file like `tail -f`: poll `path` every
/// `interval`, feeding each *complete* new line (partial trailing writes
/// wait for their newline) to `on_line`. A shrinking file — the event
/// log rotated — restarts from the head of the fresh file. `max_polls`
/// bounds the loop for scripts and tests; `None` follows forever.
pub fn follow_jsonl(
    path: &Path,
    interval: Duration,
    max_polls: Option<usize>,
    on_line: &mut dyn FnMut(&str),
) -> Result<()> {
    let mut offset = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    let mut polls = 0usize;
    loop {
        if let Some(max) = max_polls {
            if polls >= max {
                return Ok(());
            }
        }
        polls += 1;
        std::thread::sleep(interval);
        let len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        if len < offset {
            offset = 0; // rotated or truncated underneath us
        }
        if len == offset {
            continue;
        }
        // Transient read errors (mid-rotation) just wait for the next poll.
        let Ok(mut f) = std::fs::File::open(path) else {
            continue;
        };
        if f.seek(SeekFrom::Start(offset)).is_err() {
            continue;
        }
        let mut buf = String::new();
        if f.read_to_string(&mut buf).is_err() {
            continue;
        }
        let consumed = match buf.rfind('\n') {
            Some(i) => i + 1,
            None => 0,
        };
        for line in buf[..consumed].lines() {
            if !line.trim().is_empty() {
                on_line(line);
            }
        }
        offset += consumed as u64;
    }
}

/// One request's reconstructed timeline from the Chrome trace events.
#[derive(Clone, Debug, Default)]
struct TraceRow {
    id: u64,
    variant: String,
    outcome: String,
    shard: u64,
    start: u64,
    end: u64,
    queue_us: u64,
    execute_us: u64,
    respond_us: u64,
}

/// Group `<dir>/trace.json` stage events back into per-request rows.
fn load_trace_rows(dir: &Path) -> Result<Vec<TraceRow>> {
    let path = dir.join("trace.json");
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "no trace at {} — run `openacm serve` (tracing on) first",
            path.display()
        )
    })?;
    let doc = json::parse(&text)?;
    let events = doc
        .get("traceEvents")
        .and_then(json::Json::as_array)
        .context("trace.json missing traceEvents")?;
    let mut rows: std::collections::BTreeMap<u64, TraceRow> = std::collections::BTreeMap::new();
    for e in events {
        let Some(args_obj) = e.get("args") else { continue };
        let Some(id) = args_obj.get("trace").and_then(json::Json::as_u64) else {
            continue;
        };
        let name = e.get("name").and_then(json::Json::as_str).unwrap_or("");
        let ts = e.get("ts").and_then(json::Json::as_u64).unwrap_or(0);
        let dur = e.get("dur").and_then(json::Json::as_u64).unwrap_or(0);
        let row = rows.entry(id).or_default();
        row.id = id;
        if let Some(v) = args_obj.get("variant").and_then(json::Json::as_str) {
            row.variant = v.to_string();
        }
        if let Some(o) = args_obj.get("outcome").and_then(json::Json::as_str) {
            row.outcome = o.to_string();
        }
        if let Some(tid) = e.get("tid").and_then(json::Json::as_u64) {
            row.shard = tid;
        }
        if row.start == 0 || ts < row.start {
            row.start = ts;
        }
        row.end = row.end.max(ts + dur);
        match name {
            "queue" => row.queue_us += dur,
            "execute" => row.execute_us += dur,
            "respond" => row.respond_us += dur,
            _ => {}
        }
    }
    Ok(rows.into_values().collect())
}

fn cmd_trace(dir: &Path, args: &Args) -> Result<()> {
    let slowest = args.usize_or("slowest", 20)?;
    let failed_only = args.flag("failed");
    let mut rows = load_trace_rows(dir)?;
    let total = rows.len();
    if failed_only {
        rows.retain(|r| r.outcome != "delivered");
    }
    rows.sort_by_key(|r| std::cmp::Reverse(r.end.saturating_sub(r.start)));
    rows.truncate(slowest);
    if args.flag("json") {
        let items: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"trace\": {}, \"variant\": \"{}\", \"shard\": {}, \"outcome\": \"{}\", \
                     \"total_us\": {}, \"queue_us\": {}, \"execute_us\": {}, \"respond_us\": {}}}",
                    r.id,
                    r.variant,
                    r.shard,
                    r.outcome,
                    r.end.saturating_sub(r.start),
                    r.queue_us,
                    r.execute_us,
                    r.respond_us
                )
            })
            .collect();
        println!("[{}]", items.join(",\n "));
        return Ok(());
    }
    println!(
        "request timelines from {} ({} kept{}; slowest first)",
        dir.join("trace.json").display(),
        total,
        if failed_only { ", failures only" } else { "" }
    );
    let mut t = Table::new(
        "traces",
        &[
            "Trace", "Variant", "Shard", "Outcome", "Total us", "Queue us", "Exec us",
            "Respond us",
        ],
    );
    for r in &rows {
        t.row(&[
            r.id.to_string(),
            r.variant.clone(),
            r.shard.to_string(),
            r.outcome.clone(),
            r.end.saturating_sub(r.start).to_string(),
            r.queue_us.to_string(),
            r.execute_us.to_string(),
            r.respond_us.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_health(dir: &Path, args: &Args) -> Result<()> {
    let path = dir.join("snapshot.json");
    let snap = sink::load(&path).with_context(|| {
        format!(
            "no snapshot at {} — run `openacm serve` first",
            path.display()
        )
    })?;
    let slo_gauges: Vec<(&String, &i64)> = snap
        .gauges
        .iter()
        .filter(|(k, _)| k.starts_with("serve.slo."))
        .collect();
    let worst_slo_state = slo_gauges
        .iter()
        .filter(|(k, _)| k.ends_with(".state"))
        .map(|&(_, v)| *v)
        .max()
        .unwrap_or(0);
    // A circuit breaker stuck open means a variant is ejected from
    // routing and not recovering — treat it exactly like an objective
    // burning at error rate. "Stuck" needs more than a state gauge of 2
    // at snapshot time: a breaker legitimately inside its normal
    // cooldown→probe cycle also reads Open for a moment. Escalate only
    // when the `.open_ms` companion gauge (time since the breaker last
    // left Closed, refreshed on metrics ticks) shows it has been
    // unhealthy for several whole cooldown cycles.
    let cooldown_ms = snap
        .gauges
        .get("serve.breaker.cooldown_ms")
        .copied()
        .unwrap_or(0)
        .max(0);
    let stuck_after_ms = (4 * cooldown_ms).max(1000);
    let open_breakers: Vec<(&String, i64)> = snap
        .gauges
        .iter()
        .filter(|(k, v)| {
            k.starts_with("serve.breaker.") && k.ends_with(".state") && **v >= 2
        })
        .map(|(k, _)| {
            let open_ms = k
                .strip_suffix(".state")
                .and_then(|base| snap.gauges.get(&format!("{base}.open_ms")))
                .copied()
                // Older snapshots without the duration gauge keep the
                // conservative treat-open-as-stuck behavior.
                .unwrap_or(i64::MAX);
            (k, open_ms)
        })
        .filter(|&(_, open_ms)| open_ms >= stuck_after_ms)
        .collect();
    let worst_state = if open_breakers.is_empty() {
        worst_slo_state
    } else {
        worst_slo_state.max(2)
    };
    let latency = snap.histograms.get("serve.latency_us");
    let p99 = latency.map(|h| h.percentile(99.0)).unwrap_or(0);
    let exemplar = latency.and_then(|h| h.exemplar_near_percentile(99.0));
    if args.flag("json") {
        let mut fields: Vec<String> = slo_gauges
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v}"))
            .collect();
        fields.push(format!("  \"latency_p99_us\": {p99}"));
        fields.push(format!(
            "  \"latency_p99_exemplar_trace\": {}",
            exemplar.unwrap_or(0)
        ));
        fields.push(format!(
            "  \"open_breakers\": [{}]",
            open_breakers
                .iter()
                .map(|(k, _)| format!("\"{k}\""))
                .collect::<Vec<_>>()
                .join(", ")
        ));
        fields.push(format!("  \"worst_state\": {worst_state}"));
        println!("{{\n{}\n}}", fields.join(",\n"));
    } else {
        println!("SLO health from {}", path.display());
        if slo_gauges.is_empty() {
            println!("(no serve.slo.* gauges yet — run `openacm serve` with traffic)");
        } else {
            let mut t = Table::new("slo", &["Gauge", "Value"]);
            for (k, v) in &slo_gauges {
                t.row(&[(*k).clone(), v.to_string()]);
            }
            t.print();
        }
        match exemplar {
            Some(id) => println!("serve.latency_us p99 = {p99}us (exemplar trace {id})"),
            None => println!("serve.latency_us p99 = {p99}us"),
        }
        for (k, open_ms) in &open_breakers {
            if *open_ms == i64::MAX {
                println!("BURNING: circuit breaker stuck open ({k} = 2)");
            } else {
                println!("BURNING: circuit breaker stuck open ({k} = 2 for {open_ms} ms)");
            }
        }
    }
    if worst_state >= 2 {
        exit_flushed(2);
    }
    Ok(())
}

fn cmd_regress(args: &Args) -> Result<()> {
    let baseline = PathBuf::from(args.required("baseline")?);
    let current = PathBuf::from(args.str_or("current", "."));
    let tol_pct = args.f64_or("tolerance", 30.0)?;
    if !(0.0..100.0).contains(&tol_pct) {
        bail!("--tolerance must be a percentage in [0, 100), got {tol_pct}");
    }
    let tol = regress::Tolerance {
        ratio_frac: tol_pct / 100.0,
        gate_times: args.flag("times"),
        ..regress::Tolerance::default()
    };
    let report = regress::compare_dirs(&baseline, &current, &tol)?;
    let fmt = |v: Option<f64>| v.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".to_string());
    if args.flag("json") {
        let items: Vec<String> = report
            .checks
            .iter()
            .map(|c| {
                format!(
                    "{{\"bench\": \"{}\", \"metric\": \"{}\", \"baseline\": {}, \
                     \"current\": {}, \"status\": \"{}\", \"gated\": {}}}",
                    c.bench,
                    c.metric,
                    fmt(c.baseline),
                    fmt(c.current),
                    c.status.name(),
                    c.gated
                )
            })
            .collect();
        println!("[{}]", items.join(",\n "));
    } else {
        let mut t = Table::new(
            &format!(
                "perf regression gate: {} vs baseline {} (±{tol_pct}% on ratios)",
                current.display(),
                baseline.display()
            ),
            &["Bench", "Metric", "Baseline", "Current", "Delta", "Status"],
        );
        for c in &report.checks {
            t.row(&[
                c.bench.clone(),
                c.metric.clone(),
                fmt(c.baseline),
                fmt(c.current),
                c.delta_frac
                    .map(|d| format!("{:+.1}%", d * 100.0))
                    .unwrap_or_else(|| "-".to_string()),
                c.status.name().to_string(),
            ]);
        }
        t.print();
    }
    if !report.passed() {
        println!(
            "FAIL: {} regression(s) beyond tolerance",
            report.regressions()
        );
        exit_flushed(1);
    }
    println!("ok: no perf regressions");
    Ok(())
}
