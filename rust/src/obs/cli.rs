//! `openacm obs` — inspect the telemetry sink.
//!
//! * `openacm obs snapshot [--dir D] [--json]` — the merged metrics
//!   snapshot accumulated by `openacm serve` / `openacm compile`;
//! * `openacm obs tail [--dir D] [--n K] [--json]` — last K structured
//!   events from `<dir>/events.jsonl`;
//! * `openacm obs diff A.json B.json [--json]` — what happened between
//!   two snapshot files (counters/histograms subtract, gauges read from
//!   the later file).

use anyhow::{bail, Context, Result};
use std::path::PathBuf;

use super::registry::RegistrySnapshot;
use super::{json, sink};
use crate::bench::harness::Table;
use crate::util::cli::Args;

pub fn cmd_obs(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(sink::default_dir);
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("snapshot");
    match action {
        "snapshot" => {
            let path = dir.join("snapshot.json");
            let snap = sink::load(&path).with_context(|| {
                format!(
                    "no snapshot at {} — run `openacm serve` or `openacm compile` first",
                    path.display()
                )
            })?;
            if args.flag("json") {
                print!("{}", snap.to_json());
            } else {
                println!("telemetry snapshot {}", path.display());
                print_snapshot(&snap);
            }
            Ok(())
        }
        "tail" => {
            let n = args.usize_or("n", 20)?;
            cmd_tail(&dir, n, args.flag("json"))
        }
        "diff" => {
            let (Some(a), Some(b)) = (args.positional.get(1), args.positional.get(2)) else {
                bail!("usage: openacm obs diff EARLIER.json LATER.json");
            };
            let earlier = sink::load(&PathBuf::from(a))?;
            let later = sink::load(&PathBuf::from(b))?;
            let d = later.diff(&earlier);
            if args.flag("json") {
                print!("{}", d.to_json());
            } else {
                println!("telemetry diff: {a} -> {b} (gauges show the later snapshot)");
                print_snapshot(&d);
            }
            Ok(())
        }
        other => bail!("unknown obs action {other:?}; expected snapshot|tail|diff"),
    }
}

/// Human rendering shared by `snapshot` and `diff`.
pub fn print_snapshot(snap: &RegistrySnapshot) {
    if !snap.counters.is_empty() {
        let mut t = Table::new("counters", &["Name", "Value"]);
        for (k, v) in &snap.counters {
            t.row(&[k.clone(), v.to_string()]);
        }
        t.print();
    }
    if !snap.gauges.is_empty() {
        let mut t = Table::new("gauges", &["Name", "Value"]);
        for (k, v) in &snap.gauges {
            t.row(&[k.clone(), v.to_string()]);
        }
        t.print();
    }
    if !snap.histograms.is_empty() {
        let mut t = Table::new(
            "histograms (log-bucketed, percentiles approximate)",
            &["Name", "Count", "Mean", "P50", "P90", "P99", "Max"],
        );
        for (k, h) in &snap.histograms {
            t.row(&[
                k.clone(),
                h.count.to_string(),
                format!("{:.1}", h.mean()),
                h.percentile(50.0).to_string(),
                h.percentile(90.0).to_string(),
                h.percentile(99.0).to_string(),
                h.max.to_string(),
            ]);
        }
        t.print();
    }
    if snap.counters.is_empty() && snap.gauges.is_empty() && snap.histograms.is_empty() {
        println!("(empty)");
    }
}

fn cmd_tail(dir: &std::path::Path, n: usize, raw: bool) -> Result<()> {
    let path = dir.join("events.jsonl");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("no event log at {}", path.display()))?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let start = lines.len().saturating_sub(n);
    for line in &lines[start..] {
        if raw {
            println!("{line}");
            continue;
        }
        match json::parse(line) {
            Ok(doc) => {
                let ts = doc.get("ts_ms").and_then(json::Json::as_u64).unwrap_or(0);
                let sev = doc
                    .get("severity")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                let sub = doc
                    .get("subsystem")
                    .and_then(json::Json::as_str)
                    .unwrap_or("?");
                let msg = doc
                    .get("message")
                    .and_then(json::Json::as_str)
                    .unwrap_or("");
                let fields = doc
                    .get("fields")
                    .and_then(json::Json::as_object)
                    .map(|pairs| {
                        pairs
                            .iter()
                            .map(|(k, v)| {
                                format!(" {k}={}", v.as_str().unwrap_or_default())
                            })
                            .collect::<String>()
                    })
                    .unwrap_or_default();
                println!("{ts} {sev:5} [{sub}] {msg}{fields}");
            }
            // A torn/foreign line should not hide the rest of the tail.
            Err(_) => println!("{line}"),
        }
    }
    Ok(())
}
