//! A minimal JSON reader for the observability artifacts (`snapshot.json`,
//! `events.jsonl`). The build is offline with no serde; emission stays
//! hand-rolled (see [`super::registry::RegistrySnapshot::to_json`] and
//! [`crate::bench::harness::BenchJson`]), and this is the matching read
//! side — a full recursive-descent value parser, but only the subset the
//! crate itself emits is exercised.

use anyhow::{bail, Result};

/// A parsed JSON value. Numbers keep their raw text so `u64` counters
/// round-trip exactly (no f64 detour for values above 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(String),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(pairs) => Some(pairs),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(s) => s.parse().ok(),
            _ => None,
        }
    }
}

/// Parse one JSON document; trailing whitespace allowed, anything else is
/// an error.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing data at byte {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected {:?} at byte {}", c as char, *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("bad literal at byte {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    if *pos == start {
        bail!("expected a value at byte {start}");
    }
    let raw = std::str::from_utf8(&b[start..*pos])?.to_string();
    // Validate once so `as_*` accessors can't hide a malformed document.
    if raw.parse::<f64>().is_err() && raw.parse::<u64>().is_err() {
        bail!("bad number {raw:?} at byte {start}");
    }
    Ok(Json::Num(raw))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                        let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => bail!("bad escape at byte {}", *pos),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte sequences pass
                // through unmodified).
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Array(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut pairs = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Object(pairs));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        pairs.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Object(pairs));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = parse(
            r#"{"a": 1, "b": [true, null, "x\n\"y\""], "c": {"d": -2.5e3}, "big": 18446744073709551615}"#,
        )
        .unwrap();
        assert_eq!(doc.get("a").unwrap().as_u64(), Some(1));
        let arr = doc.get("b").unwrap().as_array().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[2].as_str(), Some("x\n\"y\""));
        assert_eq!(doc.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // u64::MAX survives (no f64 detour).
        assert_eq!(doc.get("big").unwrap().as_u64(), Some(u64::MAX));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("nope").is_err());
    }
}
