//! Lightweight span tracing: RAII guards that record duration histograms
//! with parent/child phase attribution.
//!
//! `obs::span("serve.batch")` opens a phase; a nested `obs::span("execute")`
//! records under `span.serve.batch/execute.us` — the slash-joined path is
//! built from a thread-local stack, so attribution needs no plumbing
//! through call signatures. Spans live at batch/probe/tile boundaries
//! only, never inside kernel inner loops.
//!
//! The switch: `OPENACM_TRACE` (default **on**; `0`/`false`/empty turns it
//! off). Disabled spans take no timestamp, touch no TLS and record
//! nothing — the cheap path the ≤2% bench guard compares against
//! (`benches/nn_forward.rs`). [`set_trace_enabled`] flips it at runtime
//! for benches and tests.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

fn trace_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| {
        let on = match std::env::var("OPENACM_TRACE") {
            Ok(v) => !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"),
            // Tracing costs one clock read + one histogram record per
            // span at coarse boundaries, so it defaults on — serving and
            // compile telemetry should not need opt-in.
            Err(_) => true,
        };
        AtomicBool::new(on)
    })
}

/// Whether spans (and the trace-gated threadpool busy-time clocks) record.
#[inline]
pub fn trace_enabled() -> bool {
    trace_flag().load(Ordering::Relaxed)
}

/// Runtime override of `OPENACM_TRACE` (bench A/B arms, tests).
pub fn set_trace_enabled(on: bool) {
    trace_flag().store(on, Ordering::Relaxed);
}

thread_local! {
    /// Stack of full span paths for the current thread (parent
    /// attribution). Innermost last.
    static STACK: RefCell<Vec<String>> = const { RefCell::new(Vec::new()) };
}

/// RAII span guard: records `span.<path>.us` on drop. Obtain via [`span`].
#[must_use = "a span records on drop; binding it to _ ends it immediately"]
#[derive(Debug)]
pub struct Span {
    start: Option<Instant>,
    path: String,
}

/// Open a span named `name`, nested under the innermost live span on this
/// thread. No-op (and allocation-free) when tracing is disabled.
pub fn span(name: &str) -> Span {
    if !trace_enabled() {
        return Span {
            start: None,
            path: String::new(),
        };
    }
    let path = STACK.with(|s| {
        let mut s = s.borrow_mut();
        let path = match s.last() {
            Some(parent) => format!("{parent}/{name}"),
            None => name.to_string(),
        };
        s.push(path.clone());
        path
    });
    Span {
        start: Some(Instant::now()),
        path,
    }
}

/// Open a span at an explicit full `path`, ignoring the caller's span
/// stack. This is for cross-thread stage attribution where the logical
/// parent lives on another thread — the pipeline's batcher opens
/// `span("serve.batch")` on its own thread, and the executor thread uses
/// `span_path("serve.batch/execute")` so the histogram name still carries
/// the parentage. Spans opened on this thread while the guard is live
/// nest under `path` as usual.
pub fn span_path(path: &str) -> Span {
    if !trace_enabled() {
        return Span {
            start: None,
            path: String::new(),
        };
    }
    STACK.with(|s| s.borrow_mut().push(path.to_string()));
    Span {
        start: Some(Instant::now()),
        path: path.to_string(),
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let us = start.elapsed().as_micros() as u64;
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            // Spans drop LIFO in practice; tolerate out-of-order drops by
            // removing this path wherever it sits.
            if let Some(pos) = s.iter().rposition(|p| *p == self.path) {
                s.remove(pos);
            }
        });
        super::registry::global()
            .histogram(&format!("span.{}.us", self.path))
            .record(us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_attribute_parent_child_and_disabled_is_free() {
        // One test body (not several) because the trace flag is global.
        let was = trace_enabled();
        set_trace_enabled(true);
        {
            let _outer = span("obs_test.outer");
            let _inner = span("inner");
        }
        let snap = super::super::registry::global().snapshot();
        assert_eq!(snap.histograms["span.obs_test.outer.us"].count, 1);
        assert_eq!(snap.histograms["span.obs_test.outer/inner.us"].count, 1);

        {
            let _stage = span_path("obs_test.remote/stage");
            let _child = span("leaf");
        }
        let snap = super::super::registry::global().snapshot();
        assert_eq!(snap.histograms["span.obs_test.remote/stage.us"].count, 1);
        assert_eq!(
            snap.histograms["span.obs_test.remote/stage/leaf.us"].count,
            1
        );

        set_trace_enabled(false);
        {
            let _off = span("obs_test.disabled");
            let _off_path = span_path("obs_test.disabled/path");
        }
        let snap = super::super::registry::global().snapshot();
        assert!(!snap.histograms.contains_key("span.obs_test.disabled.us"));
        assert!(!snap.histograms.contains_key("span.obs_test.disabled/path.us"));
        set_trace_enabled(was);
    }
}
