//! Perf-regression gate: diff the current `BENCH_*.json` emission against
//! a committed baseline directory with per-metric tolerance bands.
//!
//! The benches already leave a machine-readable trail
//! ([`crate::bench::harness::BenchJson`]: cases with ns timings, named
//! speedup **ratios**, absolute **counters**). This module turns that
//! trail into an enforced curve: `openacm obs regress --baseline
//! benches/baseline` compares every metric the baseline names and exits
//! non-zero when one regresses beyond tolerance.
//!
//! Gating policy (what CI machines make reasonable):
//!
//! * **Ratios gate by default** — they are machine-normalized speedups
//!   (blocked-over-scalar, warm-over-cold, shard4-over-shard1), stable
//!   across runner generations. Direction heuristic: a ratio whose name
//!   contains `"overhead"` is lower-is-better; every other ratio is
//!   higher-is-better.
//! * **Absolute case times are informational by default** (`--times`
//!   opts them in with their own, looser band) — wall-ns varies with the
//!   runner.
//! * **Counters never gate** — they are workload descriptors, not
//!   performance.
//! * A metric the baseline names but the current emission lacks is a
//!   **gated regression** (a bench silently dropping a tracked column is
//!   exactly what the gate exists to catch); metrics only the current
//!   emission has are reported as new, ungated.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::{parse, Json};

/// One parsed `BENCH_<name>.json` document.
#[derive(Clone, Debug, Default)]
pub struct BenchDoc {
    pub name: String,
    /// Case name → `mean_ns`.
    pub cases: Vec<(String, f64)>,
    pub ratios: Vec<(String, f64)>,
    pub counters: Vec<(String, f64)>,
}

/// Parse the format [`crate::bench::harness::BenchJson::render`] emits.
/// Non-finite metrics (serialized as `null`) are skipped.
pub fn parse_bench(text: &str) -> Result<BenchDoc> {
    let doc = parse(text)?;
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .context("bench json missing \"name\"")?
        .to_string();
    let mut out = BenchDoc {
        name,
        ..BenchDoc::default()
    };
    if let Some(cases) = doc.get("cases").and_then(Json::as_array) {
        for c in cases {
            let (Some(n), Some(v)) = (
                c.get("name").and_then(Json::as_str),
                c.get("mean_ns").and_then(Json::as_f64),
            ) else {
                continue;
            };
            out.cases.push((n.to_string(), v));
        }
    }
    for (section, into) in [("ratios", &mut out.ratios), ("counters", &mut out.counters)] {
        if let Some(obj) = doc.get(section).and_then(Json::as_object) {
            for (k, v) in obj {
                if let Some(x) = v.as_f64() {
                    into.push((k.clone(), x));
                }
            }
        }
    }
    Ok(out)
}

/// Tolerance bands; fractions of the baseline value.
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    /// Band for ratios (default 0.30: a 10× speedup may sag to 7×).
    pub ratio_frac: f64,
    /// Band for absolute case times when gated (default 0.50).
    pub time_frac: f64,
    /// Gate absolute case times too (`--times`).
    pub gate_times: bool,
}

impl Default for Tolerance {
    fn default() -> Tolerance {
        Tolerance {
            ratio_frac: 0.30,
            time_frac: 0.50,
            gate_times: false,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckStatus {
    /// Within band.
    Ok,
    /// Beyond band in the good direction.
    Improved,
    /// Beyond band in the bad direction.
    Regressed,
    /// Baseline names it; current emission lacks it.
    Missing,
    /// Current emission has it; baseline doesn't (informational).
    New,
    /// Tracked but never gated (counters; times without `--times`).
    Info,
}

impl CheckStatus {
    pub fn name(self) -> &'static str {
        match self {
            CheckStatus::Ok => "ok",
            CheckStatus::Improved => "improved",
            CheckStatus::Regressed => "REGRESSED",
            CheckStatus::Missing => "MISSING",
            CheckStatus::New => "new",
            CheckStatus::Info => "info",
        }
    }
}

/// One metric comparison.
#[derive(Clone, Debug)]
pub struct Check {
    /// Bench document name (`nn_forward`, `serving`, …).
    pub bench: String,
    /// `ratio:<name>`, `case:<name>` or `counter:<name>`.
    pub metric: String,
    pub baseline: Option<f64>,
    pub current: Option<f64>,
    /// Signed `(current - baseline) / baseline`.
    pub delta_frac: Option<f64>,
    pub lower_better: bool,
    /// Whether this check can fail the gate.
    pub gated: bool,
    pub status: CheckStatus,
}

impl Check {
    pub fn is_regression(&self) -> bool {
        self.gated && matches!(self.status, CheckStatus::Regressed | CheckStatus::Missing)
    }
}

/// Full gate result.
#[derive(Clone, Debug, Default)]
pub struct RegressReport {
    pub checks: Vec<Check>,
}

impl RegressReport {
    pub fn regressions(&self) -> usize {
        self.checks.iter().filter(|c| c.is_regression()).count()
    }

    pub fn passed(&self) -> bool {
        self.regressions() == 0
    }
}

fn lower_better(metric_kind: &str, name: &str) -> bool {
    match metric_kind {
        // Wall time: smaller is faster.
        "case" => true,
        // Speedup ratios, except self-overhead ratios (traced/untraced).
        "ratio" => name.contains("overhead"),
        _ => false,
    }
}

#[allow(clippy::too_many_arguments)]
fn check_metric(
    out: &mut Vec<Check>,
    bench: &str,
    kind: &str,
    name: &str,
    base: f64,
    cur: Option<f64>,
    band: f64,
    gated: bool,
) {
    let lower = lower_better(kind, name);
    let metric = format!("{kind}:{name}");
    let Some(cur) = cur else {
        out.push(Check {
            bench: bench.to_string(),
            metric,
            baseline: Some(base),
            current: None,
            delta_frac: None,
            lower_better: lower,
            gated,
            status: CheckStatus::Missing,
        });
        return;
    };
    let delta = if base.abs() > f64::EPSILON {
        (cur - base) / base
    } else {
        0.0
    };
    let status = if !gated {
        CheckStatus::Info
    } else if (lower && delta > band) || (!lower && delta < -band) {
        CheckStatus::Regressed
    } else if (lower && delta < -band) || (!lower && delta > band) {
        CheckStatus::Improved
    } else {
        CheckStatus::Ok
    };
    out.push(Check {
        bench: bench.to_string(),
        metric,
        baseline: Some(base),
        current: Some(cur),
        delta_frac: Some(delta),
        lower_better: lower,
        gated,
        status,
    });
}

/// Compare one bench document pair.
pub fn compare(baseline: &BenchDoc, current: &BenchDoc, tol: &Tolerance) -> Vec<Check> {
    let mut out = Vec::new();
    let find = |hay: &[(String, f64)], k: &str| {
        hay.iter().find(|(n, _)| n == k).map(|&(_, v)| v)
    };
    for (name, base) in &baseline.ratios {
        check_metric(
            &mut out,
            &baseline.name,
            "ratio",
            name,
            *base,
            find(&current.ratios, name),
            tol.ratio_frac,
            true,
        );
    }
    for (name, base) in &baseline.cases {
        check_metric(
            &mut out,
            &baseline.name,
            "case",
            name,
            *base,
            find(&current.cases, name),
            tol.time_frac,
            tol.gate_times,
        );
    }
    for (name, base) in &baseline.counters {
        check_metric(
            &mut out,
            &baseline.name,
            "counter",
            name,
            *base,
            find(&current.counters, name),
            f64::INFINITY,
            false,
        );
    }
    // Metrics the current emission gained since the baseline: surface,
    // never gate.
    for (name, cur) in &current.ratios {
        if find(&baseline.ratios, name).is_none() {
            out.push(Check {
                bench: baseline.name.clone(),
                metric: format!("ratio:{name}"),
                baseline: None,
                current: Some(*cur),
                delta_frac: None,
                lower_better: lower_better("ratio", name),
                gated: false,
                status: CheckStatus::New,
            });
        }
    }
    out
}

fn bench_files(dir: &Path) -> Result<Vec<std::path::PathBuf>> {
    let mut out = Vec::new();
    for entry in
        fs::read_dir(dir).with_context(|| format!("reading baseline dir {}", dir.display()))?
    {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

/// Compare every `BENCH_*.json` in `baseline_dir` against its
/// counterpart in `current_dir`. A baseline file with no current
/// counterpart is a gated regression — the bench stopped emitting.
pub fn compare_dirs(baseline_dir: &Path, current_dir: &Path, tol: &Tolerance) -> Result<RegressReport> {
    let files = bench_files(baseline_dir)?;
    if files.is_empty() {
        bail!("no BENCH_*.json files in {}", baseline_dir.display());
    }
    let mut report = RegressReport::default();
    for path in files {
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let base = parse_bench(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let cur_path = current_dir.join(path.file_name().expect("bench file name"));
        match fs::read_to_string(&cur_path) {
            Ok(cur_text) => {
                let cur = parse_bench(&cur_text)
                    .with_context(|| format!("parsing {}", cur_path.display()))?;
                report.checks.extend(compare(&base, &cur, tol));
            }
            Err(_) => report.checks.push(Check {
                bench: base.name.clone(),
                metric: "file".to_string(),
                baseline: None,
                current: None,
                delta_frac: None,
                lower_better: false,
                gated: true,
                status: CheckStatus::Missing,
            }),
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::{BenchJson, BenchResult};

    fn doc(ratios: &[(&str, f64)]) -> BenchDoc {
        BenchDoc {
            name: "t".to_string(),
            cases: vec![("fwd".to_string(), 1000.0)],
            ratios: ratios.iter().map(|&(n, v)| (n.to_string(), v)).collect(),
            counters: vec![("reqs".to_string(), 100.0)],
        }
    }

    #[test]
    fn parses_the_harness_emission_format() {
        let mut j = BenchJson::new("roundtrip");
        j.case(&BenchResult {
            name: "fwd b=32".into(),
            iters: 5,
            mean_ns: 1234.5,
            p50_ns: 1200.0,
            p99_ns: 1500.0,
            min_ns: 1100.0,
        });
        j.ratio("blocked_over_scalar", 7.5);
        j.ratio("obs_overhead_b32", f64::INFINITY); // serializes as null
        j.counter("requests", 4096.0);
        let doc = parse_bench(&j.render()).unwrap();
        assert_eq!(doc.name, "roundtrip");
        assert_eq!(doc.cases, vec![("fwd b=32".to_string(), 1234.5)]);
        assert_eq!(doc.ratios, vec![("blocked_over_scalar".to_string(), 7.5)]);
        assert_eq!(doc.counters, vec![("requests".to_string(), 4096.0)]);
    }

    #[test]
    fn unchanged_tree_passes_and_degradation_fails() {
        let tol = Tolerance::default();
        let base = doc(&[("speedup", 8.0), ("obs_overhead", 1.01)]);
        // Identical emission: every gated check Ok.
        let same = compare(&base, &base, &tol);
        assert!(same.iter().all(|c| !c.is_regression()));
        assert!(same
            .iter()
            .any(|c| c.metric == "ratio:speedup" && c.status == CheckStatus::Ok));

        // Speedup sagging beyond the 30% band is a regression…
        let worse = doc(&[("speedup", 4.0), ("obs_overhead", 1.01)]);
        let checks = compare(&base, &worse, &tol);
        let r = checks.iter().find(|c| c.metric == "ratio:speedup").unwrap();
        assert_eq!(r.status, CheckStatus::Regressed);
        assert!(r.is_regression());
        // …and an overhead ratio *growing* beyond band is too
        // (lower-is-better direction heuristic).
        let slow = doc(&[("speedup", 8.0), ("obs_overhead", 2.0)]);
        let checks = compare(&base, &slow, &tol);
        let r = checks.iter().find(|c| c.metric == "ratio:obs_overhead").unwrap();
        assert!(r.lower_better);
        assert_eq!(r.status, CheckStatus::Regressed);

        // Within band: ok. Far better: improved, not a regression.
        let better = doc(&[("speedup", 20.0), ("obs_overhead", 1.0)]);
        let checks = compare(&base, &better, &tol);
        let r = checks.iter().find(|c| c.metric == "ratio:speedup").unwrap();
        assert_eq!(r.status, CheckStatus::Improved);
        assert!(!r.is_regression());
    }

    #[test]
    fn missing_tracked_metric_gates_and_new_metric_does_not() {
        let tol = Tolerance::default();
        let base = doc(&[("speedup", 8.0)]);
        let dropped = doc(&[]);
        let checks = compare(&base, &dropped, &tol);
        let r = checks.iter().find(|c| c.metric == "ratio:speedup").unwrap();
        assert_eq!(r.status, CheckStatus::Missing);
        assert!(r.is_regression());

        let gained = doc(&[("speedup", 8.0), ("extra", 2.0)]);
        let checks = compare(&base, &gained, &tol);
        let n = checks.iter().find(|c| c.metric == "ratio:extra").unwrap();
        assert_eq!(n.status, CheckStatus::New);
        assert!(!n.is_regression());
    }

    #[test]
    fn times_gate_only_when_opted_in_and_counters_never() {
        let base = doc(&[]);
        let mut slower = base.clone();
        slower.cases[0].1 = 10_000.0; // 10× slower
        slower.counters[0].1 = 9999.0; // counters drift freely
        let default_tol = Tolerance::default();
        let checks = compare(&base, &slower, &default_tol);
        assert_eq!(
            checks.iter().filter(|c| c.is_regression()).count(),
            0,
            "{checks:?}"
        );
        let strict = Tolerance {
            gate_times: true,
            ..Tolerance::default()
        };
        let checks = compare(&base, &slower, &strict);
        let r = checks.iter().find(|c| c.metric == "case:fwd").unwrap();
        assert_eq!(r.status, CheckStatus::Regressed);
        assert!(r.is_regression());
        let c = checks.iter().find(|c| c.metric == "counter:reqs").unwrap();
        assert_eq!(c.status, CheckStatus::Info);
        assert!(!c.is_regression());
    }

    #[test]
    fn dir_comparison_flags_a_missing_bench_file() {
        let root = std::env::temp_dir().join(format!("openacm-regress-{}", std::process::id()));
        let base_dir = root.join("baseline");
        let cur_dir = root.join("current");
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&base_dir).unwrap();
        fs::create_dir_all(&cur_dir).unwrap();
        let mut j = BenchJson::new("solo");
        j.ratio("speedup", 4.0);
        fs::write(base_dir.join("BENCH_solo.json"), j.render()).unwrap();

        // No current file at all: gated regression.
        let report = compare_dirs(&base_dir, &cur_dir, &Tolerance::default()).unwrap();
        assert_eq!(report.regressions(), 1);
        assert!(!report.passed());

        // Matching file: passes.
        fs::write(cur_dir.join("BENCH_solo.json"), j.render()).unwrap();
        let report = compare_dirs(&base_dir, &cur_dir, &Tolerance::default()).unwrap();
        assert!(report.passed());
        let _ = fs::remove_dir_all(&root);
    }
}
