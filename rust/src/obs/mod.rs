//! `obs::` — the unified telemetry spine: one metrics registry, span
//! tracing and a structured event log shared by the serving coordinator,
//! the compile search, the design-point store, SIMD dispatch and the
//! threadpool (DESIGN.md §Observability).
//!
//! Three pillars, pure std (offline/vendored policy — no new deps):
//!
//! * [`registry`] — process-wide named **counters**, **gauges** and
//!   fixed-memory log-bucketed **histograms** on sharded atomics;
//!   lock-free record path, mergeable [`RegistrySnapshot`]s.
//! * [`span`] — `obs::span("compile.probe")` RAII guards recording
//!   `span.<path>.us` duration histograms with parent/child path
//!   attribution; `OPENACM_TRACE` (default on) switches them off with a
//!   no-timestamp, no-TLS cheap path.
//! * [`event`] — severity/timestamp/subsystem/key=value **JSONL events**
//!   absorbing the old bare `eprintln!`s, with stderr mirroring for
//!   Warn/Error preserved by default.
//!
//! Naming convention: `<subsystem>.<metric>` with `_us` / `_bytes`
//! suffixes for units (`serve.latency_us`, `store.hits`,
//! `compile.replayed_macs`, `simd.widened_fallback_strips`,
//! `threadpool.busy_us`); span histograms are `span.<path>.us`.
//!
//! On top of the pillars sits the analysis layer:
//!
//! * [`trace`] — per-request **stage timelines** (admit → batch →
//!   execute → respond) with tail-based sampling: every failed, shed or
//!   deadline-missed request keeps its full timeline, plus the top-K
//!   slowest and a probabilistic slice of healthy traffic; exported as
//!   Chrome trace-event JSON (`<dir>/trace.json`) and linked into
//!   latency histograms as per-bucket **exemplar** trace ids.
//! * [`slo`] — sliding-window **burn-rate engine** over availability,
//!   latency and routing-health objectives (Google-SRE fast/slow window
//!   pairs), publishing `serve.slo.*` gauges and Warn/Error transition
//!   events.
//! * [`regress`] — **perf-regression gate** diffing `BENCH_*.json`
//!   emissions against a committed baseline with tolerance bands.
//!
//! Persistence: [`sink::flush`] merge-writes `<dir>/snapshot.json`
//! (default dir `$OPENACM_OBS` / `.openacm_obs`) so consecutive commands
//! accumulate one telemetry trail; `openacm obs
//! snapshot|tail|diff|trace|health|regress` ([`cli`]) reads it back.
//! Overhead budget: instrumentation sits at batch/probe/GEMM boundaries
//! only — `benches/nn_forward.rs` enforces ≤2% on the hot forward path
//! vs `OPENACM_TRACE=0`, a guard the regression gate keeps honest via
//! the `obs_overhead_b32` ratio.

pub mod cli;
pub mod event;
pub mod json;
pub mod registry;
pub mod regress;
pub mod sink;
pub mod slo;
pub mod span;
pub mod trace;

pub use event::{emit, error, info, recent, warn, Event, Severity};
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, RegistrySnapshot,
};
pub use sink::{default_dir, flush, init, load};
pub use slo::{SloEngine, SloInput, SloPolicy, SloState};
pub use span::{set_trace_enabled, span, span_path, trace_enabled, Span};
pub use trace::{StageStamps, TraceOutcome};

use std::sync::OnceLock;

/// Get-or-register a counter in the process-wide registry.
pub fn counter(name: &str) -> Counter {
    registry::global().counter(name)
}

/// Get-or-register a gauge in the process-wide registry.
pub fn gauge(name: &str) -> Gauge {
    registry::global().gauge(name)
}

/// Get-or-register a histogram in the process-wide registry.
pub fn histogram(name: &str) -> Histogram {
    registry::global().histogram(name)
}

/// Snapshot the process-wide registry.
pub fn snapshot() -> RegistrySnapshot {
    registry::global().snapshot()
}

/// SIMD dispatch accounting for one blocked-GEMM call, invoked at the
/// GEMM boundary (never inside the strip loops): total calls, and how
/// many strips ran the i64-widened overflow-fallback path. Handles are
/// cached so the per-call cost is 1–3 relaxed `fetch_add`s.
pub fn record_gemm_dispatch(widened: bool, strips: u64) {
    struct Handles {
        calls: Counter,
        widened_gemms: Counter,
        widened_strips: Counter,
    }
    static H: OnceLock<Handles> = OnceLock::new();
    let h = H.get_or_init(|| Handles {
        calls: counter("simd.gemm_calls"),
        widened_gemms: counter("simd.widened_fallback_gemms"),
        widened_strips: counter("simd.widened_fallback_strips"),
    });
    h.calls.inc();
    if widened {
        h.widened_gemms.inc();
        h.widened_strips.add(strips);
    }
}

/// Threadpool accounting: `n` tasks entered a pool/`parallel_map` call.
pub fn record_pool_tasks(n: u64) {
    static TASKS: OnceLock<Counter> = OnceLock::new();
    TASKS.get_or_init(|| counter("threadpool.tasks")).add(n);
}

/// Threadpool accounting: one worker was busy for `us` microseconds
/// (recorded per drained work loop; only called when tracing is on, so
/// the disabled path pays no clock reads).
pub fn record_pool_busy_us(us: u64) {
    static BUSY: OnceLock<Counter> = OnceLock::new();
    BUSY.get_or_init(|| counter("threadpool.busy_us")).add(us);
}
