//! On-disk telemetry sink: `<dir>/snapshot.json` + `<dir>/events.jsonl`.
//!
//! `flush` writes the global registry merged **on top of whatever the file
//! held when this process first flushed** — so `openacm compile` followed
//! by `openacm serve` accumulate into one snapshot (the property the
//! `openacm obs snapshot` acceptance check relies on), while periodic
//! flushes from one process (`serve --metrics-every N`) never double-count
//! their own metrics. Writes are temp-file + atomic rename, same as the
//! design-point store.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use super::registry::{global, RegistrySnapshot};

/// Default sink root: `$OPENACM_OBS` or `.openacm_obs` in the working
/// directory (mirrors [`crate::store::DesignPointStore::default_dir`]).
pub fn default_dir() -> PathBuf {
    std::env::var("OPENACM_OBS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(".openacm_obs"))
}

/// Create the sink dir and start appending events to
/// `<dir>/events.jsonl`.
pub fn init(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating obs dir {}", dir.display()))?;
    super::event::attach_file(&dir.join("events.jsonl"))
        .with_context(|| format!("opening event log in {}", dir.display()))?;
    Ok(())
}

/// Per-dir baseline: the snapshot found on disk the first time this
/// process flushed there. Every flush rewrites `baseline + live registry`.
fn baselines() -> &'static Mutex<HashMap<PathBuf, RegistrySnapshot>> {
    static BASE: OnceLock<Mutex<HashMap<PathBuf, RegistrySnapshot>>> = OnceLock::new();
    BASE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Write the merged snapshot to `<dir>/snapshot.json`; returns its path.
pub fn flush(dir: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating obs dir {}", dir.display()))?;
    let path = dir.join("snapshot.json");
    let mut merged = {
        let mut base = baselines().lock().unwrap();
        base.entry(dir.to_path_buf())
            .or_insert_with(|| {
                // A missing or corrupt prior snapshot degrades to an
                // empty baseline — telemetry must never fail a command.
                load(&path).unwrap_or_default()
            })
            .clone()
    };
    merged.merge(&global().snapshot());
    let tmp = dir.join(format!(".snapshot-{}.tmp", std::process::id()));
    std::fs::write(&tmp, merged.to_json())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(path)
}

/// Read a snapshot file written by [`flush`].
pub fn load(path: &Path) -> Result<RegistrySnapshot> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    RegistrySnapshot::from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_merges_onto_preexisting_snapshot_without_double_counting() {
        let dir = std::env::temp_dir().join(format!("openacm-obs-sink-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Seed the file as if another process had flushed 100 earlier.
        std::fs::create_dir_all(&dir).unwrap();
        let mut prior = RegistrySnapshot::default();
        prior.counters.insert("obs_sink_test.prior".into(), 100);
        std::fs::write(dir.join("snapshot.json"), prior.to_json()).unwrap();

        let c = global().counter("obs_sink_test.live");
        c.add(7);
        let path = flush(&dir).unwrap();
        let live_now = global().counter("obs_sink_test.live").value();
        let first = load(&path).unwrap();
        assert_eq!(first.counters["obs_sink_test.prior"], 100);
        assert!(first.counters["obs_sink_test.live"] >= live_now.min(7));

        // A second flush must not re-add the prior file's 100 again.
        let second = load(&flush(&dir).unwrap()).unwrap();
        assert_eq!(second.counters["obs_sink_test.prior"], 100);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
