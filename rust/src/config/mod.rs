//! Configuration system: the architecture specification consumed by the
//! compiler (SRAM organization, multiplier family and accuracy knobs, timing
//! controls) plus a small TOML-subset parser so specs can live in files.

pub mod spec;
pub mod toml;

pub use spec::{
    CompressorKind, MacroSpec, MultFamily, MultSpec, SramSpec, TimingKnobs,
};
pub use toml::TomlDoc;
