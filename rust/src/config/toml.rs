//! TOML-subset parser for spec files (no `serde`/`toml` offline).
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / boolean values, `#` comments. That is all the
//! spec files need. Keys are flattened to `section.sub.key`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::config::spec::{CompressorKind, MacroSpec, MultFamily, MultSpec, SramSpec, TimingKnobs};

/// A parsed document: flat `section.key` → raw value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                let name = h.trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                prefix = format!("{name}.");
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = format!("{prefix}{}", k.trim());
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value for {key}", lineno + 1))?;
            if doc.values.insert(key.clone(), value).is_some() {
                bail!("line {}: duplicate key {key}", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<TomlDoc> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&src)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.values.get(key) {
            Some(TomlValue::Int(i)) => Some(*i),
            _ => None,
        }
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(TomlValue::Float(f)) => Some(*f),
            Some(TomlValue::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Iterate over every flattened `section.key` in the document.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|k| k.as_str())
    }

    /// Reject any key [`Self::to_macro_spec`] would not consume. A
    /// misspelled knob (`aprox_cols`) silently falling back to its
    /// default is the worst failure mode a spec loader can have — the
    /// user asked for one design and characterizes another. Unknown keys
    /// are a hard error, with a "did you mean" suggestion when a known
    /// key is within small edit distance.
    pub fn check_known_keys(&self) -> Result<()> {
        // Must list exactly the keys `to_macro_spec` reads — when adding a
        // getter there, add its key here (and to the
        // `all_documented_keys_are_accepted` test, which pins the overlap).
        const KNOWN: &[&str] = &[
            "name",
            "sram.rows",
            "sram.word_bits",
            "sram.banks",
            "sram.subarrays",
            "sram.mux_ratio",
            "sram.sae_delay_ps",
            "sram.precharge_ps",
            "sram.wl_pulse_ps",
            "mult.family",
            "mult.compressor",
            "mult.approx_cols",
            "mult.bits",
            "mult.signed",
            "target.clock_mhz",
            "target.load_pf",
        ];
        for key in self.keys() {
            if KNOWN.contains(&key) {
                continue;
            }
            let nearest = KNOWN
                .iter()
                .map(|k| (levenshtein(key, k), *k))
                .min()
                .expect("KNOWN is non-empty");
            // Suggest only plausible typos (distance within a third of
            // the known key's length, minimum 2).
            if nearest.0 <= (nearest.1.len() / 3).max(2) {
                bail!(
                    "unknown spec key {key:?} — did you mean {:?}?",
                    nearest.1
                );
            }
            bail!("unknown spec key {key:?}");
        }
        Ok(())
    }

    /// Build a [`MacroSpec`] from a parsed document. Unknown keys are
    /// rejected ([`Self::check_known_keys`]) before anything is read.
    ///
    /// Expected layout (all keys optional except dimensions):
    /// ```toml
    /// name = "dcim16x8"
    /// [sram]
    /// rows = 16
    /// word_bits = 8
    /// banks = 1
    /// subarrays = 1
    /// mux_ratio = 1
    /// sae_delay_ps = 180.0
    /// [mult]
    /// family = "appro42"        # exact | appro42 | logour | mitchell | adder_tree
    /// compressor = "yang1"
    /// approx_cols = 8
    /// bits = 8
    /// signed = false
    /// [target]
    /// clock_mhz = 100.0
    /// load_pf = 0.5
    /// ```
    pub fn to_macro_spec(&self) -> Result<MacroSpec> {
        self.check_known_keys()?;
        let rows = self
            .get_int("sram.rows")
            .context("missing sram.rows")? as usize;
        let word_bits = self
            .get_int("sram.word_bits")
            .context("missing sram.word_bits")? as usize;
        let mut sram = SramSpec::new(rows, word_bits);
        if let Some(b) = self.get_int("sram.banks") {
            sram.banks = b as usize;
        }
        if let Some(s) = self.get_int("sram.subarrays") {
            sram.subarrays = s as usize;
        }
        if let Some(m) = self.get_int("sram.mux_ratio") {
            sram.mux_ratio = m as usize;
        }
        let mut t = TimingKnobs::default();
        if let Some(v) = self.get_float("sram.sae_delay_ps") {
            t.sae_delay_ps = v;
        }
        if let Some(v) = self.get_float("sram.precharge_ps") {
            t.precharge_ps = v;
        }
        if let Some(v) = self.get_float("sram.wl_pulse_ps") {
            t.wl_pulse_ps = v;
        }
        sram.timing = t;

        let bits = self
            .get_int("mult.bits")
            .map(|b| b as usize)
            .unwrap_or(word_bits);
        let family = match self.get_str("mult.family").unwrap_or("exact") {
            "exact" => MultFamily::Exact,
            "logour" | "log-our" => MultFamily::LogOur,
            "mitchell" | "lm" => MultFamily::Mitchell,
            "adder_tree" | "openc2" => MultFamily::AdderTree,
            "appro42" | "approx42" => {
                let comp = CompressorKind::parse(
                    self.get_str("mult.compressor").unwrap_or("yang1"),
                )?;
                let cols = self
                    .get_int("mult.approx_cols")
                    .map(|c| c as usize)
                    .unwrap_or(bits);
                MultFamily::Approx42 {
                    compressor: comp,
                    approx_cols: cols,
                }
            }
            other => bail!("unknown mult.family {other:?}"),
        };
        let spec = MacroSpec {
            name: self
                .get_str("name")
                .unwrap_or(&format!("dcim{rows}x{word_bits}"))
                .to_string(),
            sram,
            mult: MultSpec {
                family,
                bits,
                signed: self.get_bool("mult.signed").unwrap_or(false),
            },
            clock_mhz: self.get_float("target.clock_mhz").unwrap_or(100.0),
            load_pf: self.get_float("target.load_pf").unwrap_or(0.5),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Classic two-row Levenshtein edit distance (insert/delete/substitute,
/// unit costs) — small enough to run on every unknown key without
/// mattering.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn strip_comment(line: &str) -> &str {
    // No string-escape subtleties needed: comments only start outside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .context("unterminated string value")?;
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a spec file
name = "demo"

[sram]
rows = 32          # power of two
word_bits = 16
banks = 2
mux_ratio = 2

[mult]
family = "appro42"
compressor = "yang1"
approx_cols = 16
signed = false

[target]
clock_mhz = 100.0
load_pf = 0.5
"#;

    #[test]
    fn parses_sample() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("name"), Some("demo"));
        assert_eq!(doc.get_int("sram.rows"), Some(32));
        assert_eq!(doc.get_float("target.clock_mhz"), Some(100.0));
        assert_eq!(doc.get_bool("mult.signed"), Some(false));
    }

    #[test]
    fn builds_macro_spec() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        let spec = doc.to_macro_spec().unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.sram.rows, 32);
        assert_eq!(spec.sram.banks, 2);
        assert_eq!(spec.mult.bits, 16);
        match &spec.mult.family {
            MultFamily::Approx42 {
                compressor,
                approx_cols,
            } => {
                assert_eq!(*compressor, CompressorKind::Yang1);
                assert_eq!(*approx_cols, 16);
            }
            other => panic!("wrong family {other:?}"),
        }
    }

    #[test]
    fn rejects_duplicates_and_bad_lines() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("x = @@@").is_err());
    }

    #[test]
    fn comments_and_strings() {
        let doc = TomlDoc::parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.get_str("s"), Some("a # not comment"));
    }

    #[test]
    fn missing_required_keys() {
        let doc = TomlDoc::parse("name = \"x\"").unwrap();
        assert!(doc.to_macro_spec().is_err());
    }

    #[test]
    fn misspelled_key_is_rejected_with_suggestion() {
        // Regression: a misspelled `approx_cols` used to be silently
        // ignored, so the spec characterized the *default* column budget
        // instead of the requested one.
        let src = SAMPLE.replace("approx_cols = 16", "aprox_cols = 16");
        let err = TomlDoc::parse(&src).unwrap().to_macro_spec().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("mult.aprox_cols"), "message: {msg}");
        assert!(
            msg.contains("did you mean") && msg.contains("mult.approx_cols"),
            "message: {msg}"
        );
    }

    #[test]
    fn unknown_key_without_plausible_match_is_still_rejected() {
        let err = TomlDoc::parse("zzz_entirely_unrelated = 3\n[sram]\nrows = 16\nword_bits = 8")
            .unwrap()
            .to_macro_spec()
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown spec key"), "message: {msg}");
        assert!(!msg.contains("did you mean"), "message: {msg}");
    }

    #[test]
    fn all_documented_keys_are_accepted() {
        let src = r#"
name = "full"
[sram]
rows = 16
word_bits = 8
banks = 1
subarrays = 1
mux_ratio = 1
sae_delay_ps = 180.0
precharge_ps = 250.0
wl_pulse_ps = 450.0
[mult]
family = "appro42"
compressor = "yang1"
approx_cols = 8
bits = 8
signed = false
[target]
clock_mhz = 100.0
load_pf = 0.5
"#;
        TomlDoc::parse(src).unwrap().to_macro_spec().unwrap();
    }

    #[test]
    fn levenshtein_reference_cases() {
        assert_eq!(super::levenshtein("", ""), 0);
        assert_eq!(super::levenshtein("abc", "abc"), 0);
        assert_eq!(super::levenshtein("abc", ""), 3);
        assert_eq!(super::levenshtein("kitten", "sitting"), 3);
        assert_eq!(
            super::levenshtein("mult.aprox_cols", "mult.approx_cols"),
            1
        );
    }
}
