//! Architecture specification types — the compiler's input.
//!
//! A [`MacroSpec`] fully describes one DCiM macro: the SRAM organization
//! (rows, word width, banks/subarrays, column-mux ratio, timing knobs) and
//! the arithmetic core (multiplier family + accuracy configuration). The
//! three Table II configurations are provided as presets.

use anyhow::{bail, Result};

/// Approximate 4-2 compressor designs available in the library.
/// Truth tables and error statistics live in `mult::compressor`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressorKind {
    /// Exact 4-2 compressor (two cascaded full adders).
    Exact,
    /// Yang et al. 2015-family design used as the paper's representative
    /// ("Yang1"): carry = x1x2 + x3x4, sum = (x1^x2) + (x3^x4).
    Yang1,
    /// Momeni et al. 2015-family design: XOR-exact sum, AND-OR carry.
    Momeni,
    /// Ha & Lee 2018-family design with error-recovery-friendly carry.
    HaLee,
    /// Kong & Li 2021-family high-accuracy design.
    Kong,
    /// Strollo et al. 2020-family compressor ("CM3"-like).
    StrolloCm3,
    /// Akbari et al. 2017 dual-quality style (approximate mode).
    DualQuality,
}

impl CompressorKind {
    pub fn name(self) -> &'static str {
        match self {
            CompressorKind::Exact => "exact",
            CompressorKind::Yang1 => "yang1",
            CompressorKind::Momeni => "momeni",
            CompressorKind::HaLee => "ha_lee",
            CompressorKind::Kong => "kong",
            CompressorKind::StrolloCm3 => "strollo_cm3",
            CompressorKind::DualQuality => "dual_quality",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exact" => CompressorKind::Exact,
            "yang1" => CompressorKind::Yang1,
            "momeni" => CompressorKind::Momeni,
            "ha_lee" => CompressorKind::HaLee,
            "kong" => CompressorKind::Kong,
            "strollo_cm3" => CompressorKind::StrolloCm3,
            "dual_quality" => CompressorKind::DualQuality,
            other => bail!("unknown compressor kind {other:?}"),
        })
    }

    pub fn all_approx() -> &'static [CompressorKind] {
        &[
            CompressorKind::Yang1,
            CompressorKind::Momeni,
            CompressorKind::HaLee,
            CompressorKind::Kong,
            CompressorKind::StrolloCm3,
            CompressorKind::DualQuality,
        ]
    }
}

/// Multiplier families (paper §III-B/§III-C + baselines).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum MultFamily {
    /// Exact 4-2-compressor (Dadda-style) multiplier.
    Exact,
    /// Tunable approximate multiplier: `compressor` on PP columns
    /// `0..approx_cols`, exact 4-2 compressors elsewhere (Fig 2 red box).
    Approx42 {
        compressor: CompressorKind,
        approx_cols: usize,
    },
    /// Proposed logarithmic multiplier with adder-free dynamic
    /// compensation (Fig 3, Eq. 3).
    LogOur,
    /// Conventional Mitchell logarithmic multiplier [24] (AP only).
    Mitchell,
    /// OpenC²-style AND-array + ripple adder-tree multiplier (baseline).
    AdderTree,
}

impl MultFamily {
    pub fn name(&self) -> String {
        match self {
            MultFamily::Exact => "exact".into(),
            MultFamily::Approx42 {
                compressor,
                approx_cols,
            } => format!("appro42[{}x{}]", compressor.name(), approx_cols),
            MultFamily::LogOur => "log-our".into(),
            MultFamily::Mitchell => "lm-mitchell".into(),
            MultFamily::AdderTree => "adder-tree".into(),
        }
    }

    /// Short label matching the paper's table rows.
    pub fn paper_label(&self) -> &'static str {
        match self {
            MultFamily::Exact => "Exact",
            MultFamily::Approx42 { .. } => "Appro4-2",
            MultFamily::LogOur => "Log-our",
            MultFamily::Mitchell => "LM [24]",
            MultFamily::AdderTree => "OpenC2",
        }
    }

    /// The paper's default Appro4-2 configuration: Yang1 compressors on PP
    /// columns #0..#7 (the Fig 2 red box — "approximate 4-2 compressors are
    /// commonly applied in the lower 8 bits of the PPs"), independent of
    /// the multiplier width. Used by the application-level evaluations
    /// (Tables III/IV).
    pub fn default_approx(bits: usize) -> MultFamily {
        MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: bits.min(8),
        }
    }

    /// The Table II Appro4-2 configuration: approximate compressors on the
    /// lower *half* of the product columns, scaling with the width (this is
    /// what gives the 14–17% power savings the paper reports at 16/32-bit).
    pub fn table2_approx(bits: usize) -> MultFamily {
        MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: bits,
        }
    }
}

/// SRAM timing control knobs (compiler-visible, paper §III-D item 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimingKnobs {
    /// Sense-amp enable delay after WL assert, in ps.
    pub sae_delay_ps: f64,
    /// Precharge pulse width, ps.
    pub precharge_ps: f64,
    /// Wordline pulse width, ps.
    pub wl_pulse_ps: f64,
}

impl Default for TimingKnobs {
    fn default() -> Self {
        Self {
            sae_delay_ps: 180.0,
            precharge_ps: 250.0,
            wl_pulse_ps: 450.0,
        }
    }
}

/// SRAM organization (paper §III-D).
#[derive(Clone, Debug, PartialEq)]
pub struct SramSpec {
    /// Total word rows.
    pub rows: usize,
    /// Word width in bits (= one operand's width in the PE).
    pub word_bits: usize,
    /// Number of banks.
    pub banks: usize,
    /// Subarrays per bank.
    pub subarrays: usize,
    /// Column multiplexing ratio (1 = none).
    pub mux_ratio: usize,
    pub timing: TimingKnobs,
}

impl SramSpec {
    pub fn new(rows: usize, word_bits: usize) -> Self {
        Self {
            rows,
            word_bits,
            banks: 1,
            subarrays: 1,
            mux_ratio: 1,
            timing: TimingKnobs::default(),
        }
    }

    /// Physical columns = word bits × mux ratio.
    pub fn phys_cols(&self) -> usize {
        self.word_bits * self.mux_ratio
    }

    /// Rows per subarray.
    pub fn rows_per_subarray(&self) -> usize {
        self.rows / (self.banks * self.subarrays)
    }

    /// Total bit cells.
    pub fn total_cells(&self) -> usize {
        self.rows * self.word_bits
    }

    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.word_bits == 0 {
            bail!("SRAM rows/word_bits must be nonzero");
        }
        if !self.rows.is_power_of_two() {
            bail!("SRAM rows must be a power of two (decoder), got {}", self.rows);
        }
        if self.rows % (self.banks * self.subarrays) != 0 {
            bail!(
                "rows {} not divisible by banks*subarrays {}",
                self.rows,
                self.banks * self.subarrays
            );
        }
        if !matches!(self.mux_ratio, 1 | 2 | 4 | 8) {
            bail!("mux_ratio must be 1/2/4/8, got {}", self.mux_ratio);
        }
        Ok(())
    }
}

/// Multiplier specification.
#[derive(Clone, Debug, PartialEq)]
pub struct MultSpec {
    pub family: MultFamily,
    /// Operand width in bits.
    pub bits: usize,
    /// Signed (sign-magnitude wrapped) operation.
    pub signed: bool,
}

impl MultSpec {
    pub fn validate(&self) -> Result<()> {
        if !(2..=32).contains(&self.bits) {
            bail!("multiplier bits must be in 2..=32, got {}", self.bits);
        }
        if let MultFamily::Approx42 { approx_cols, .. } = &self.family {
            if *approx_cols > 2 * self.bits {
                bail!(
                    "approx_cols {} exceeds product width {}",
                    approx_cols,
                    2 * self.bits
                );
            }
        }
        if self.signed && self.bits < 2 {
            bail!("signed multiplier needs >= 2 bits");
        }
        Ok(())
    }
}

/// Full DCiM macro specification: the compiler's top-level input.
#[derive(Clone, Debug, PartialEq)]
pub struct MacroSpec {
    pub name: String,
    pub sram: SramSpec,
    pub mult: MultSpec,
    /// Target clock frequency, MHz (paper: 100 MHz).
    pub clock_mhz: f64,
    /// Output load, pF (paper: 0.5 pF).
    pub load_pf: f64,
}

impl MacroSpec {
    pub fn new(name: &str, rows: usize, word_bits: usize, family: MultFamily) -> Self {
        Self {
            name: name.to_string(),
            sram: SramSpec::new(rows, word_bits),
            mult: MultSpec {
                family,
                bits: word_bits,
                signed: false,
            },
            clock_mhz: 100.0,
            load_pf: 0.5,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.sram.validate()?;
        self.mult.validate()?;
        if self.clock_mhz <= 0.0 || self.load_pf < 0.0 {
            bail!("bad clock/load");
        }
        Ok(())
    }

    /// The three Table II configurations for a given multiplier family.
    pub fn table2_presets(family: MultFamily) -> Vec<MacroSpec> {
        vec![
            MacroSpec::new(
                &format!("dcim16x8_{}", family.name()),
                16,
                8,
                family.clone(),
            ),
            MacroSpec::new(
                &format!("dcim32x16_{}", family.name()),
                32,
                16,
                family.clone(),
            ),
            MacroSpec::new(&format!("dcim64x32_{}", family.name()), 64, 32, family),
        ]
    }

    /// All four Table II multiplier families at the given width.
    pub fn table2_families(bits: usize) -> Vec<MultFamily> {
        vec![
            MultFamily::AdderTree,
            MultFamily::Exact,
            MultFamily::LogOur,
            MultFamily::table2_approx(bits),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_configs() {
        let ps = MacroSpec::table2_presets(MultFamily::Exact);
        assert_eq!(ps.len(), 3);
        assert_eq!((ps[0].sram.rows, ps[0].sram.word_bits), (16, 8));
        assert_eq!((ps[1].sram.rows, ps[1].sram.word_bits), (32, 16));
        assert_eq!((ps[2].sram.rows, ps[2].sram.word_bits), (64, 32));
        for p in &ps {
            p.validate().unwrap();
            assert!((p.clock_mhz - 100.0).abs() < 1e-9);
            assert!((p.load_pf - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = SramSpec::new(17, 8);
        assert!(s.validate().is_err()); // non power of two
        s.rows = 16;
        s.mux_ratio = 3;
        assert!(s.validate().is_err());
        let m = MultSpec {
            family: MultFamily::Exact,
            bits: 1,
            signed: false,
        };
        assert!(m.validate().is_err());
        let m2 = MultSpec {
            family: MultFamily::Approx42 {
                compressor: CompressorKind::Yang1,
                approx_cols: 64,
            },
            bits: 8,
            signed: false,
        };
        assert!(m2.validate().is_err());
    }

    #[test]
    fn compressor_name_roundtrip() {
        for k in CompressorKind::all_approx() {
            assert_eq!(CompressorKind::parse(k.name()).unwrap(), *k);
        }
        assert!(CompressorKind::parse("nope").is_err());
    }

    #[test]
    fn default_approx_covers_lower_half() {
        // 8-bit multiplier → columns #0..#7 approximate (Fig 2 red box).
        if let MultFamily::Approx42 { approx_cols, .. } = MultFamily::default_approx(8) {
            assert_eq!(approx_cols, 8);
        } else {
            panic!("wrong family");
        }
    }

    #[test]
    fn sram_derived_quantities() {
        let mut s = SramSpec::new(64, 32);
        s.banks = 2;
        s.subarrays = 2;
        s.mux_ratio = 2;
        assert_eq!(s.phys_cols(), 64);
        assert_eq!(s.rows_per_subarray(), 16);
        assert_eq!(s.total_cells(), 2048);
        s.validate().unwrap();
    }
}
