//! The netlist data structure and its evaluators.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Dense net identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NetId(pub u32);

impl NetId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Primitive gate kinds. Two-input gates plus inverter, buffer, constants
/// and a 2:1 mux (select, a, b → s ? b : a). This basis is what the
/// FreePDK45-class cell library provides; wider functions are decomposed by
/// the builder.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GateKind {
    Const0,
    Const1,
    Input,
    Buf,
    Not,
    And2,
    Or2,
    Xor2,
    Nand2,
    Nor2,
    Xnor2,
    /// out = sel ? b : a   (inputs: [a, b, sel])
    Mux2,
}

impl GateKind {
    pub fn arity(self) -> usize {
        match self {
            GateKind::Const0 | GateKind::Const1 | GateKind::Input => 0,
            GateKind::Buf | GateKind::Not => 1,
            GateKind::Mux2 => 3,
            _ => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GateKind::Const0 => "const0",
            GateKind::Const1 => "const1",
            GateKind::Input => "input",
            GateKind::Buf => "buf",
            GateKind::Not => "not",
            GateKind::And2 => "and2",
            GateKind::Or2 => "or2",
            GateKind::Xor2 => "xor2",
            GateKind::Nand2 => "nand2",
            GateKind::Nor2 => "nor2",
            GateKind::Xnor2 => "xnor2",
            GateKind::Mux2 => "mux2",
        }
    }
}

/// One gate instance. `output` is always the net with id equal to the
/// gate's position + its own slot, assigned by the netlist.
#[derive(Clone, Debug)]
pub struct Gate {
    pub kind: GateKind,
    pub inputs: [NetId; 3],
    pub output: NetId,
}

/// A combinational netlist with named ports.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub name: String,
    gates: Vec<Gate>,
    /// Primary inputs in declaration order.
    inputs: Vec<(String, NetId)>,
    /// Primary outputs in declaration order.
    outputs: Vec<(String, NetId)>,
    /// Optional debug names for internal nets.
    net_names: BTreeMap<NetId, String>,
}

impl Netlist {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn net_count(&self) -> usize {
        self.gates.len()
    }

    pub fn gates(&self) -> &[Gate] {
        &self.gates
    }

    pub fn inputs(&self) -> &[(String, NetId)] {
        &self.inputs
    }

    pub fn outputs(&self) -> &[(String, NetId)] {
        &self.outputs
    }

    /// Gate count excluding inputs/constants (what area models count).
    pub fn logic_gate_count(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| {
                !matches!(
                    g.kind,
                    GateKind::Input | GateKind::Const0 | GateKind::Const1
                )
            })
            .count()
    }

    /// Histogram of gate kinds.
    pub fn kind_counts(&self) -> BTreeMap<GateKind, usize> {
        let mut m = BTreeMap::new();
        for g in &self.gates {
            *m.entry(g.kind).or_insert(0) += 1;
        }
        m
    }

    /// Fanout count per net (how many gate inputs it drives) + primary
    /// outputs count as one load each. Used by the timing/power models.
    pub fn fanouts(&self) -> Vec<u32> {
        let mut f = vec![0u32; self.gates.len()];
        for g in &self.gates {
            for i in 0..g.kind.arity() {
                f[g.inputs[i].idx()] += 1;
            }
        }
        for (_, n) in &self.outputs {
            f[n.idx()] += 1;
        }
        f
    }

    pub(crate) fn push_gate(&mut self, kind: GateKind, inputs: [NetId; 3]) -> NetId {
        let out = NetId(self.gates.len() as u32);
        for i in 0..kind.arity() {
            assert!(
                inputs[i].0 < out.0,
                "netlist must be built topologically: gate {} input {} >= output {}",
                self.gates.len(),
                inputs[i].0,
                out.0
            );
        }
        self.gates.push(Gate {
            kind,
            inputs,
            output: out,
        });
        out
    }

    pub(crate) fn add_input(&mut self, name: &str) -> NetId {
        let id = self.push_gate(GateKind::Input, [NetId(0); 3]);
        self.inputs.push((name.to_string(), id));
        self.net_names.insert(id, name.to_string());
        id
    }

    pub(crate) fn mark_output(&mut self, name: &str, net: NetId) {
        self.outputs.push((name.to_string(), net));
    }

    pub fn name_net(&mut self, net: NetId, name: &str) {
        self.net_names.insert(net, name.to_string());
    }

    pub fn net_name(&self, net: NetId) -> Option<&str> {
        self.net_names.get(&net).map(|s| s.as_str())
    }

    /// Append the canonical structural byte encoding of this netlist —
    /// gate kinds + connectivity (arity-many inputs only) and the port
    /// declarations, all length-prefixed and little-endian. Instance
    /// `name` and debug `net_names` are deliberately excluded, so two
    /// structurally identical circuits encode identically regardless of
    /// how they were labelled. This is the content-addressing basis for
    /// the design-point store (`store::KeyBuilder::netlist`).
    pub fn canonical_bytes(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.gates.len() as u32).to_le_bytes());
        for g in &self.gates {
            out.push(g.kind as u8);
            for i in 0..g.kind.arity() {
                out.extend_from_slice(&g.inputs[i].0.to_le_bytes());
            }
        }
        let ports = |out: &mut Vec<u8>, list: &[(String, NetId)]| {
            out.extend_from_slice(&(list.len() as u32).to_le_bytes());
            for (name, id) in list {
                out.extend_from_slice(&(name.len() as u32).to_le_bytes());
                out.extend_from_slice(name.as_bytes());
                out.extend_from_slice(&id.0.to_le_bytes());
            }
        };
        ports(out, &self.inputs);
        ports(out, &self.outputs);
    }

    /// Validate structural invariants (topological order, port references).
    pub fn validate(&self) -> Result<()> {
        for (i, g) in self.gates.iter().enumerate() {
            if g.output.idx() != i {
                bail!("gate {i} output id mismatch");
            }
            for k in 0..g.kind.arity() {
                if g.inputs[k].idx() >= i {
                    bail!("gate {i} reads a later net {}", g.inputs[k].0);
                }
            }
        }
        for (n, id) in &self.outputs {
            if id.idx() >= self.gates.len() {
                bail!("output {n} references missing net");
            }
        }
        Ok(())
    }

    /// Evaluate 64 input vectors at once. `assignment[i]` holds the 64
    /// parallel sample bits for primary input `i` (declaration order).
    /// Returns all net values (indexable by `NetId`).
    pub fn eval_u64(&self, assignment: &[u64]) -> Vec<u64> {
        let mut vals = Vec::new();
        self.eval_u64_into(assignment, &mut vals);
        vals
    }

    /// [`Netlist::eval_u64`] into a caller-owned buffer, so sweep loops
    /// (activity extraction, exhaustive characterization) evaluate without
    /// a per-batch allocation. The buffer is resized to the net count.
    pub fn eval_u64_into(&self, assignment: &[u64], vals: &mut Vec<u64>) {
        assert_eq!(
            assignment.len(),
            self.inputs.len(),
            "assignment arity mismatch"
        );
        vals.clear();
        vals.resize(self.gates.len(), 0u64);
        let mut next_input = 0;
        for (i, g) in self.gates.iter().enumerate() {
            let a = g.inputs[0];
            let b = g.inputs[1];
            vals[i] = match g.kind {
                GateKind::Const0 => 0,
                GateKind::Const1 => u64::MAX,
                GateKind::Input => {
                    let v = assignment[next_input];
                    next_input += 1;
                    v
                }
                GateKind::Buf => vals[a.idx()],
                GateKind::Not => !vals[a.idx()],
                GateKind::And2 => vals[a.idx()] & vals[b.idx()],
                GateKind::Or2 => vals[a.idx()] | vals[b.idx()],
                GateKind::Xor2 => vals[a.idx()] ^ vals[b.idx()],
                GateKind::Nand2 => !(vals[a.idx()] & vals[b.idx()]),
                GateKind::Nor2 => !(vals[a.idx()] | vals[b.idx()]),
                GateKind::Xnor2 => !(vals[a.idx()] ^ vals[b.idx()]),
                GateKind::Mux2 => {
                    let s = vals[g.inputs[2].idx()];
                    (vals[a.idx()] & !s) | (vals[b.idx()] & s)
                }
            };
        }
    }

    /// Evaluate `words × 64` input vectors in one topological sweep: every
    /// net carries a *plane-group* of `words` consecutive `u64` bit-planes
    /// (word `w`, lane `l` = vector `w·64 + l`). `assignment` is
    /// input-major — input `i`'s group at `[i·words .. (i+1)·words]` — and
    /// `vals` comes back net-major with the same per-net layout, so net
    /// `n`'s word `w` sits at `vals[n·words + w]`. With `words == 1` this
    /// is exactly [`Netlist::eval_u64_into`].
    ///
    /// Every gate op is pure bitwise and identical per word, so the result
    /// is bit-identical to `words` separate [`Netlist::eval_u64_into`]
    /// sweeps regardless of dispatch tier; when [`crate::util::simd`]
    /// detects AVX2 the 4-word groups are evaluated with 256-bit ops (and
    /// 2-word groups auto-vectorize to NEON on aarch64). This is the
    /// engine under [`crate::sim::BitParallelSim`]'s wide path, exhaustive
    /// error characterization and the functional-yield Monte-Carlo.
    pub fn eval_wide_into(&self, assignment: &[u64], words: usize, vals: &mut Vec<u64>) {
        assert!(words >= 1, "at least one plane word");
        assert_eq!(
            assignment.len(),
            self.inputs.len() * words,
            "assignment arity mismatch"
        );
        match words {
            1 => self.eval_u64_into(assignment, vals),
            2 => {
                #[cfg(target_arch = "aarch64")]
                if crate::util::simd::detect() == crate::util::simd::SimdLevel::Neon {
                    // SAFETY: NEON support was verified at runtime.
                    unsafe { self.eval_planes_neon(assignment, vals) };
                    return;
                }
                self.eval_planes::<2>(assignment, vals);
            }
            4 => {
                #[cfg(target_arch = "x86_64")]
                if crate::util::simd::detect() == crate::util::simd::SimdLevel::Avx2 {
                    // SAFETY: AVX2 support was verified at runtime.
                    unsafe { self.eval_planes_avx2(assignment, vals) };
                    return;
                }
                self.eval_planes::<4>(assignment, vals);
            }
            _ => self.eval_planes_dyn(assignment, words, vals),
        }
    }

    /// Shared plane-group body: `W` words per net, unrolled by the const
    /// generic. `#[inline(always)]` so the `target_feature` wrappers below
    /// compile it *inside* their feature scope, letting LLVM fold each
    /// group into full-width vector ops.
    #[inline(always)]
    fn eval_planes<const W: usize>(&self, assignment: &[u64], vals: &mut Vec<u64>) {
        vals.clear();
        vals.resize(self.gates.len() * W, 0u64);
        let v = vals.as_mut_slice();
        let mut next_input = 0;
        for (i, g) in self.gates.iter().enumerate() {
            let o = i * W;
            let a = g.inputs[0].idx() * W;
            let b = g.inputs[1].idx() * W;
            match g.kind {
                GateKind::Const0 => {} // groups start zeroed
                GateKind::Const1 => {
                    for w in 0..W {
                        v[o + w] = u64::MAX;
                    }
                }
                GateKind::Input => {
                    let src = &assignment[next_input * W..(next_input + 1) * W];
                    v[o..o + W].copy_from_slice(src);
                    next_input += 1;
                }
                GateKind::Buf => {
                    v.copy_within(a..a + W, o);
                }
                GateKind::Not => {
                    for w in 0..W {
                        v[o + w] = !v[a + w];
                    }
                }
                GateKind::And2 => {
                    for w in 0..W {
                        v[o + w] = v[a + w] & v[b + w];
                    }
                }
                GateKind::Or2 => {
                    for w in 0..W {
                        v[o + w] = v[a + w] | v[b + w];
                    }
                }
                GateKind::Xor2 => {
                    for w in 0..W {
                        v[o + w] = v[a + w] ^ v[b + w];
                    }
                }
                GateKind::Nand2 => {
                    for w in 0..W {
                        v[o + w] = !(v[a + w] & v[b + w]);
                    }
                }
                GateKind::Nor2 => {
                    for w in 0..W {
                        v[o + w] = !(v[a + w] | v[b + w]);
                    }
                }
                GateKind::Xnor2 => {
                    for w in 0..W {
                        v[o + w] = !(v[a + w] ^ v[b + w]);
                    }
                }
                GateKind::Mux2 => {
                    let s = g.inputs[2].idx() * W;
                    for w in 0..W {
                        let sv = v[s + w];
                        v[o + w] = (v[a + w] & !sv) | (v[b + w] & sv);
                    }
                }
            }
        }
    }

    /// [`Netlist::eval_planes`] compiled with AVX2 enabled: each 4-word
    /// plane group becomes one 256-bit lane vector.
    ///
    /// # Safety
    /// The caller must have verified AVX2 support at runtime
    /// ([`crate::util::simd::detect`]).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn eval_planes_avx2(&self, assignment: &[u64], vals: &mut Vec<u64>) {
        self.eval_planes::<4>(assignment, vals);
    }

    /// [`Netlist::eval_planes`] compiled with NEON enabled: each 2-word
    /// plane group becomes one 128-bit lane vector.
    ///
    /// # Safety
    /// The caller must have verified NEON support at runtime (always true
    /// on aarch64 std targets, still checked for uniformity).
    #[cfg(target_arch = "aarch64")]
    #[target_feature(enable = "neon")]
    unsafe fn eval_planes_neon(&self, assignment: &[u64], vals: &mut Vec<u64>) {
        self.eval_planes::<2>(assignment, vals);
    }

    /// Arbitrary-width fallback (API totality; the dispatched widths are
    /// 1/2/4): evaluate one column at a time through the scalar engine and
    /// scatter into the net-major group layout. Bit-identical by
    /// construction.
    fn eval_planes_dyn(&self, assignment: &[u64], words: usize, vals: &mut Vec<u64>) {
        vals.clear();
        vals.resize(self.gates.len() * words, 0u64);
        let mut col = Vec::new();
        let mut a_col = vec![0u64; self.inputs.len()];
        for w in 0..words {
            for (i, chunk) in assignment.chunks_exact(words).enumerate() {
                a_col[i] = chunk[w];
            }
            self.eval_u64_into(&a_col, &mut col);
            for (net, &x) in col.iter().enumerate() {
                vals[net * words + w] = x;
            }
        }
    }

    /// Single-vector evaluation: map named input bits to a named output
    /// word. Inputs/outputs are bit-vectors in declaration order.
    pub fn eval_words(&self, input_bits: &[bool]) -> Vec<bool> {
        let assignment: Vec<u64> = input_bits
            .iter()
            .map(|&b| if b { u64::MAX } else { 0 })
            .collect();
        let vals = self.eval_u64(&assignment);
        self.outputs
            .iter()
            .map(|(_, id)| vals[id.idx()] & 1 != 0)
            .collect()
    }

    /// Convenience for arithmetic blocks: inputs given as unsigned words per
    /// declared *input group*. The builder declares inputs LSB-first with
    /// names like `a[0]`, `a[1]`, …; this helper splits on the `[` to group.
    pub fn eval_uint(&self, operands: &BTreeMap<String, u64>) -> BTreeMap<String, u64> {
        let mut bits = Vec::with_capacity(self.inputs.len());
        let mut counters: BTreeMap<String, u32> = BTreeMap::new();
        for (name, _) in &self.inputs {
            let group = name.split('[').next().unwrap().to_string();
            let bit = counters.entry(group.clone()).or_insert(0);
            let val = operands
                .get(&group)
                .unwrap_or_else(|| panic!("missing operand {group}"));
            bits.push((val >> *bit) & 1 != 0);
            *bit += 1;
        }
        let out_bits = self.eval_words(&bits);
        let mut outs: BTreeMap<String, u64> = BTreeMap::new();
        let mut counters: BTreeMap<String, u32> = BTreeMap::new();
        for ((name, _), b) in self.outputs.iter().zip(out_bits) {
            let group = name.split('[').next().unwrap().to_string();
            let bit = counters.entry(group.clone()).or_insert(0);
            let e = outs.entry(group).or_insert(0);
            if b {
                *e |= 1 << *bit;
            }
            *bit += 1;
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::builder::Builder;

    #[test]
    fn topological_invariant_enforced() {
        let mut nl = Netlist::new("t");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let o = nl.push_gate(GateKind::And2, [a, b, NetId(0)]);
        nl.mark_output("o", o);
        nl.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "topologically")]
    fn forward_reference_panics() {
        let mut nl = Netlist::new("t");
        let a = nl.add_input("a");
        // Reference a net that doesn't exist yet.
        nl.push_gate(GateKind::And2, [a, NetId(99), NetId(0)]);
    }

    #[test]
    fn eval_all_primitive_gates() {
        let mut nl = Netlist::new("prims");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let s = nl.add_input("s");
        let and = nl.push_gate(GateKind::And2, [a, b, NetId(0)]);
        let or = nl.push_gate(GateKind::Or2, [a, b, NetId(0)]);
        let xor = nl.push_gate(GateKind::Xor2, [a, b, NetId(0)]);
        let nand = nl.push_gate(GateKind::Nand2, [a, b, NetId(0)]);
        let nor = nl.push_gate(GateKind::Nor2, [a, b, NetId(0)]);
        let xnor = nl.push_gate(GateKind::Xnor2, [a, b, NetId(0)]);
        let not = nl.push_gate(GateKind::Not, [a, NetId(0), NetId(0)]);
        let mux = nl.push_gate(GateKind::Mux2, [a, b, s]);
        for (name, id) in [
            ("and", and),
            ("or", or),
            ("xor", xor),
            ("nand", nand),
            ("nor", nor),
            ("xnor", xnor),
            ("not", not),
            ("mux", mux),
        ] {
            nl.mark_output(name, id);
        }
        for av in [0u64, 1] {
            for bv in [0u64, 1] {
                for sv in [0u64, 1] {
                    let vals = nl.eval_u64(&[
                        if av == 1 { u64::MAX } else { 0 },
                        if bv == 1 { u64::MAX } else { 0 },
                        if sv == 1 { u64::MAX } else { 0 },
                    ]);
                    let get = |id: NetId| vals[id.idx()] & 1;
                    assert_eq!(get(and), av & bv);
                    assert_eq!(get(or), av | bv);
                    assert_eq!(get(xor), av ^ bv);
                    assert_eq!(get(nand), 1 - (av & bv));
                    assert_eq!(get(nor), 1 - (av | bv));
                    assert_eq!(get(xnor), 1 - (av ^ bv));
                    assert_eq!(get(not), 1 - av);
                    assert_eq!(get(mux), if sv == 1 { bv } else { av });
                }
            }
        }
    }

    #[test]
    fn bit_parallel_matches_serial() {
        // A small adder evaluated 64 inputs at a time must agree with
        // serial single-vector evaluation.
        let mut b = Builder::new("add4");
        let x = b.input_bus("x", 4);
        let y = b.input_bus("y", 4);
        let (sum, carry) = b.ripple_add(&x, &y);
        b.output_bus("s", &sum);
        b.output_bit("c", carry);
        let nl = b.finish();
        for xv in 0..16u64 {
            for yv in 0..16u64 {
                let mut ops = BTreeMap::new();
                ops.insert("x".to_string(), xv);
                ops.insert("y".to_string(), yv);
                let out = nl.eval_uint(&ops);
                let total = out["s"] | (out["c"] << 4);
                assert_eq!(total, xv + yv, "{xv}+{yv}");
            }
        }
    }

    #[test]
    fn canonical_bytes_ignore_labels_but_not_structure() {
        let build = |kind: GateKind| {
            let mut nl = Netlist::new("x");
            let a = nl.add_input("a");
            let b = nl.add_input("b");
            let o = nl.push_gate(kind, [a, b, NetId(0)]);
            nl.mark_output("o", o);
            nl
        };
        let mut base = Vec::new();
        build(GateKind::And2).canonical_bytes(&mut base);
        // Instance name and debug net names don't change the encoding...
        let mut relabelled = build(GateKind::And2);
        relabelled.name = "renamed".into();
        relabelled.name_net(NetId(2), "debug");
        let mut rl = Vec::new();
        relabelled.canonical_bytes(&mut rl);
        assert_eq!(base, rl);
        // ...but a gate kind or a port name does.
        let mut other = Vec::new();
        build(GateKind::Or2).canonical_bytes(&mut other);
        assert_ne!(base, other);
        let mut renamed_port = build(GateKind::And2);
        renamed_port.outputs[0].0 = "q".into();
        let mut rp = Vec::new();
        renamed_port.canonical_bytes(&mut rp);
        assert_ne!(base, rp);
    }

    #[test]
    fn wide_plane_groups_match_column_by_column_eval() {
        // eval_wide_into(words=W) must equal W independent eval_u64_into
        // sweeps, one per word — for the dispatched widths and an odd one.
        let mut b = Builder::new("add4");
        let x = b.input_bus("x", 4);
        let y = b.input_bus("y", 4);
        let (sum, carry) = b.ripple_add(&x, &y);
        b.output_bus("s", &sum);
        b.output_bit("c", carry);
        let nl = b.finish();
        let n_in = nl.inputs().len();
        let mut seed = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            seed
        };
        for words in [1usize, 2, 3, 4] {
            let assignment: Vec<u64> = (0..n_in * words).map(|_| next()).collect();
            let mut wide = Vec::new();
            nl.eval_wide_into(&assignment, words, &mut wide);
            assert_eq!(wide.len(), nl.gates().len() * words);
            let mut col_in = vec![0u64; n_in];
            let mut col_out = Vec::new();
            for w in 0..words {
                for i in 0..n_in {
                    col_in[i] = assignment[i * words + w];
                }
                nl.eval_u64_into(&col_in, &mut col_out);
                for (net, &v) in col_out.iter().enumerate() {
                    assert_eq!(wide[net * words + w], v, "words={words} w={w} net={net}");
                }
            }
        }
    }

    #[test]
    fn fanout_counts() {
        let mut nl = Netlist::new("f");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let x = nl.push_gate(GateKind::And2, [a, b, NetId(0)]);
        let y = nl.push_gate(GateKind::Or2, [a, x, NetId(0)]);
        nl.mark_output("y", y);
        let f = nl.fanouts();
        assert_eq!(f[a.idx()], 2); // feeds and + or
        assert_eq!(f[b.idx()], 1);
        assert_eq!(f[x.idx()], 1);
        assert_eq!(f[y.idx()], 1); // primary output load
    }
}
