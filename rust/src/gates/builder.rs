//! Structural builder: composable arithmetic blocks over the netlist IR.
//!
//! All multi-bit buses are LSB-first `Vec<NetId>`. Blocks provided here are
//! exactly the primitives the multiplier generators need: half/full adders,
//! ripple and carry-propagate adders, subtractors, shifters (fixed and
//! barrel), leading-one detector, priority encoder, binary decoder,
//! magnitude comparator and wide OR/AND reductions.

use super::netlist::{GateKind, NetId, Netlist};

/// Netlist builder with typed helpers.
pub struct Builder {
    nl: Netlist,
    zero: Option<NetId>,
    one: Option<NetId>,
}

impl Builder {
    pub fn new(name: &str) -> Self {
        Self {
            nl: Netlist::new(name),
            zero: None,
            one: None,
        }
    }

    pub fn finish(self) -> Netlist {
        self.nl
    }

    // ---- primitive wiring -------------------------------------------------

    pub fn input(&mut self, name: &str) -> NetId {
        self.nl.add_input(name)
    }

    /// Declare an LSB-first input bus `name[0..width)`.
    pub fn input_bus(&mut self, name: &str, width: usize) -> Vec<NetId> {
        (0..width)
            .map(|i| self.nl.add_input(&format!("{name}[{i}]")))
            .collect()
    }

    pub fn output_bit(&mut self, name: &str, net: NetId) {
        self.nl.mark_output(name, net);
    }

    pub fn output_bus(&mut self, name: &str, bits: &[NetId]) {
        for (i, b) in bits.iter().enumerate() {
            self.nl.mark_output(&format!("{name}[{i}]"), *b);
        }
    }

    pub fn zero(&mut self) -> NetId {
        if let Some(z) = self.zero {
            return z;
        }
        let z = self.nl.push_gate(GateKind::Const0, [NetId(0); 3]);
        self.zero = Some(z);
        z
    }

    pub fn one(&mut self) -> NetId {
        if let Some(o) = self.one {
            return o;
        }
        let o = self.nl.push_gate(GateKind::Const1, [NetId(0); 3]);
        self.one = Some(o);
        o
    }

    pub fn not(&mut self, a: NetId) -> NetId {
        self.nl.push_gate(GateKind::Not, [a, NetId(0), NetId(0)])
    }

    pub fn and(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::And2, [a, b, NetId(0)])
    }

    pub fn or(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Or2, [a, b, NetId(0)])
    }

    pub fn xor(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Xor2, [a, b, NetId(0)])
    }

    pub fn nand(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Nand2, [a, b, NetId(0)])
    }

    pub fn nor(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Nor2, [a, b, NetId(0)])
    }

    pub fn xnor(&mut self, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Xnor2, [a, b, NetId(0)])
    }

    /// sel ? b : a
    pub fn mux(&mut self, sel: NetId, a: NetId, b: NetId) -> NetId {
        self.nl.push_gate(GateKind::Mux2, [a, b, sel])
    }

    pub fn and3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        let t = self.and(a, b);
        self.and(t, c)
    }

    pub fn or3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        let t = self.or(a, b);
        self.or(t, c)
    }

    pub fn xor3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        let t = self.xor(a, b);
        self.xor(t, c)
    }

    /// Majority(a, b, c) = ab + ac + bc (carry function).
    pub fn maj(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        let ab = self.and(a, b);
        let axb = self.xor(a, b);
        let c_axb = self.and(axb, c);
        self.or(ab, c_axb)
    }

    // ---- adders -----------------------------------------------------------

    /// Half adder → (sum, carry).
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        (self.xor(a, b), self.and(a, b))
    }

    /// Full adder → (sum, carry).
    pub fn full_adder(&mut self, a: NetId, b: NetId, cin: NetId) -> (NetId, NetId) {
        let s = self.xor3(a, b, cin);
        let c = self.maj(a, b, cin);
        (s, c)
    }

    /// Ripple-carry adder over equal-width buses → (sum bus, carry-out).
    pub fn ripple_add(&mut self, a: &[NetId], b: &[NetId]) -> (Vec<NetId>, NetId) {
        assert_eq!(a.len(), b.len());
        let mut carry = self.zero();
        let mut sum = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, c) = self.full_adder(a[i], b[i], carry);
            sum.push(s);
            carry = c;
        }
        (sum, carry)
    }

    /// Add buses of (possibly) different widths; result width =
    /// max(len) + 1 (carry appended).
    pub fn add_extend(&mut self, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        let w = a.len().max(b.len());
        let z = self.zero();
        let ax: Vec<NetId> = (0..w).map(|i| *a.get(i).unwrap_or(&z)).collect();
        let bx: Vec<NetId> = (0..w).map(|i| *b.get(i).unwrap_or(&z)).collect();
        let (mut s, c) = self.ripple_add(&ax, &bx);
        s.push(c);
        s
    }

    /// a - b (two's complement), buses equal width → (diff, borrow-free flag
    /// i.e. carry-out; carry==1 means a >= b).
    pub fn ripple_sub(&mut self, a: &[NetId], b: &[NetId]) -> (Vec<NetId>, NetId) {
        assert_eq!(a.len(), b.len());
        let mut carry = self.one();
        let mut diff = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let nb = self.not(b[i]);
            let (s, c) = self.full_adder(a[i], nb, carry);
            diff.push(s);
            carry = c;
        }
        (diff, carry)
    }

    /// Increment bus by 1 → (result, carry-out).
    pub fn increment(&mut self, a: &[NetId]) -> (Vec<NetId>, NetId) {
        let mut carry = self.one();
        let mut out = Vec::with_capacity(a.len());
        for &bit in a {
            let (s, c) = self.half_adder(bit, carry);
            out.push(s);
            carry = c;
        }
        (out, carry)
    }

    // ---- selection / shifting ----------------------------------------------

    /// Bitwise mux over buses: sel ? b : a.
    pub fn mux_bus(&mut self, sel: NetId, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(&x, &y)| self.mux(sel, x, y))
            .collect()
    }

    /// Logical left-shift by a constant, keeping `width` output bits.
    pub fn shl_const(&mut self, a: &[NetId], k: usize, width: usize) -> Vec<NetId> {
        let z = self.zero();
        (0..width)
            .map(|i| {
                if i >= k && i - k < a.len() {
                    a[i - k]
                } else {
                    z
                }
            })
            .collect()
    }

    /// Barrel shifter: left-shift `a` by the unsigned value of `amount`
    /// (LSB-first), producing `width` output bits. log-depth mux stages.
    pub fn barrel_shl(&mut self, a: &[NetId], amount: &[NetId], width: usize) -> Vec<NetId> {
        let z = self.zero();
        let mut cur: Vec<NetId> = (0..width)
            .map(|i| if i < a.len() { a[i] } else { z })
            .collect();
        for (stage, &sel) in amount.iter().enumerate() {
            let k = 1usize << stage;
            if k >= width {
                // Shifting by >= width zeroes everything when sel is set.
                cur = cur.iter().map(|&bit| self.mux(sel, bit, z)).collect();
                continue;
            }
            let shifted: Vec<NetId> = (0..width)
                .map(|i| if i >= k { cur[i - k] } else { z })
                .collect();
            cur = self.mux_bus(sel, &cur, &shifted);
        }
        cur
    }

    // ---- encoders / decoders -----------------------------------------------

    /// Log-depth suffix-OR: `out[i] = a[i] | a[i+1] | … | a[n-1]`
    /// (doubling prefix network, O(n log n) gates, O(log n) depth).
    pub fn suffix_or(&mut self, a: &[NetId]) -> Vec<NetId> {
        let n = a.len();
        let mut cur = a.to_vec();
        let mut step = 1;
        while step < n {
            let mut next = cur.clone();
            for i in 0..n {
                if i + step < n {
                    next[i] = self.or(cur[i], cur[i + step]);
                }
            }
            cur = next;
            step *= 2;
        }
        cur
    }

    /// Leading-one detector: one-hot output, bit i set iff `a[i]` is the
    /// most significant set bit. All-zero input → all-zero output.
    /// Log-depth via the suffix-OR network (the LoD sits on the log
    /// multiplier's critical path — Fig 3).
    pub fn leading_one_detector(&mut self, a: &[NetId]) -> Vec<NetId> {
        let n = a.len();
        let any_above = self.suffix_or(a);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            if i + 1 < n {
                let na = self.not(any_above[i + 1]);
                out.push(self.and(a[i], na));
            } else {
                out.push(a[i]);
            }
        }
        out
    }

    /// Priority encoder over a one-hot bus → binary index (LSB-first,
    /// ceil(log2 n) bits). Assumes at most one bit set.
    pub fn onehot_encode(&mut self, onehot: &[NetId]) -> Vec<NetId> {
        let n = onehot.len();
        let bits = usize::BITS as usize - (n - 1).leading_zeros() as usize;
        let mut out = Vec::with_capacity(bits);
        for b in 0..bits {
            // OR of all onehot positions whose index has bit b set.
            let mut acc: Option<NetId> = None;
            for (i, &h) in onehot.iter().enumerate() {
                if (i >> b) & 1 == 1 {
                    acc = Some(match acc {
                        None => h,
                        Some(prev) => self.or(prev, h),
                    });
                }
            }
            let z = self.zero();
            out.push(acc.unwrap_or(z));
        }
        out
    }

    /// Binary decoder: `sel` (LSB-first) → one-hot of 2^sel.len() outputs.
    pub fn decoder(&mut self, sel: &[NetId]) -> Vec<NetId> {
        let n = 1usize << sel.len();
        let nsel: Vec<NetId> = sel.iter().map(|&s| self.not(s)).collect();
        (0..n)
            .map(|i| {
                let mut acc: Option<NetId> = None;
                for (b, (&s, &ns)) in sel.iter().zip(&nsel).enumerate() {
                    let term = if (i >> b) & 1 == 1 { s } else { ns };
                    acc = Some(match acc {
                        None => term,
                        Some(prev) => self.and(prev, term),
                    });
                }
                acc.expect("decoder needs >= 1 select bit")
            })
            .collect()
    }

    /// Unsigned magnitude comparator → (a_gt_b, a_eq_b). Binary-tree
    /// combination (`gt = gt_hi | (eq_hi & gt_lo)`), log depth — the COMP
    /// block sits on the Log-our critical path.
    pub fn compare(&mut self, a: &[NetId], b: &[NetId]) -> (NetId, NetId) {
        assert_eq!(a.len(), b.len());
        // Per-bit (gt, eq).
        let mut nodes: Vec<(NetId, NetId)> = (0..a.len())
            .map(|i| {
                let nb = self.not(b[i]);
                let gt = self.and(a[i], nb);
                let eq = self.xnor(a[i], b[i]);
                (gt, eq)
            })
            .collect();
        // Reduce pairwise, MSB side dominating.
        while nodes.len() > 1 {
            let mut next = Vec::with_capacity(nodes.len().div_ceil(2));
            let mut it = nodes.chunks(2);
            for ch in &mut it {
                if ch.len() == 1 {
                    next.push(ch[0]);
                } else {
                    let (gt_lo, eq_lo) = ch[0];
                    let (gt_hi, eq_hi) = ch[1];
                    let t = self.and(eq_hi, gt_lo);
                    let gt = self.or(gt_hi, t);
                    let eq = self.and(eq_hi, eq_lo);
                    next.push((gt, eq));
                }
            }
            nodes = next;
        }
        nodes[0]
    }

    /// Wide OR reduction.
    pub fn or_reduce(&mut self, xs: &[NetId]) -> NetId {
        match xs.len() {
            0 => self.zero(),
            1 => xs[0],
            _ => {
                // Balanced tree for shallow depth.
                let mid = xs.len() / 2;
                let l = self.or_reduce(&xs[..mid]);
                let r = self.or_reduce(&xs[mid..]);
                self.or(l, r)
            }
        }
    }

    /// Wide AND reduction.
    pub fn and_reduce(&mut self, xs: &[NetId]) -> NetId {
        match xs.len() {
            0 => self.one(),
            1 => xs[0],
            _ => {
                let mid = xs.len() / 2;
                let l = self.and_reduce(&xs[..mid]);
                let r = self.and_reduce(&xs[mid..]);
                self.and(l, r)
            }
        }
    }

    /// Bitwise OR of two equal-width buses.
    pub fn or_bus(&mut self, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.or(x, y)).collect()
    }

    /// Bitwise XOR of two equal-width buses.
    pub fn xor_bus(&mut self, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(&x, &y)| self.xor(x, y)).collect()
    }

    /// Bitwise AND of a bus with a single control bit.
    pub fn gate_bus(&mut self, ctrl: NetId, a: &[NetId]) -> Vec<NetId> {
        a.iter().map(|&x| self.and(ctrl, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn run1(nl: &Netlist, ins: &[(&str, u64)]) -> BTreeMap<String, u64> {
        let mut m = BTreeMap::new();
        for (k, v) in ins {
            m.insert(k.to_string(), *v);
        }
        nl.eval_uint(&m)
    }

    #[test]
    fn full_adder_truth_table() {
        let mut b = Builder::new("fa");
        let a = b.input("a[0]");
        let x = b.input("b[0]");
        let c = b.input("c[0]");
        let (s, co) = b.full_adder(a, x, c);
        b.output_bit("s[0]", s);
        b.output_bit("co[0]", co);
        let nl = b.finish();
        for av in 0..2u64 {
            for bv in 0..2u64 {
                for cv in 0..2u64 {
                    let out = run1(&nl, &[("a", av), ("b", bv), ("c", cv)]);
                    let total = out["s"] + 2 * out["co"];
                    assert_eq!(total, av + bv + cv);
                }
            }
        }
    }

    #[test]
    fn subtractor_and_compare() {
        let mut b = Builder::new("sub");
        let x = b.input_bus("x", 6);
        let y = b.input_bus("y", 6);
        let (d, c) = b.ripple_sub(&x, &y);
        let (gt, eq) = b.compare(&x, &y);
        b.output_bus("d", &d);
        b.output_bit("c[0]", c);
        b.output_bit("gt[0]", gt);
        b.output_bit("eq[0]", eq);
        let nl = b.finish();
        for xv in 0..64u64 {
            for yv in 0..64u64 {
                let out = run1(&nl, &[("x", xv), ("y", yv)]);
                assert_eq!(out["d"], xv.wrapping_sub(yv) & 63, "{xv}-{yv}");
                assert_eq!(out["c"], (xv >= yv) as u64);
                assert_eq!(out["gt"], (xv > yv) as u64);
                assert_eq!(out["eq"], (xv == yv) as u64);
            }
        }
    }

    #[test]
    fn barrel_shifter_exhaustive() {
        let mut b = Builder::new("shl");
        let a = b.input_bus("a", 8);
        let k = b.input_bus("k", 3);
        let out = b.barrel_shl(&a, &k, 16);
        b.output_bus("o", &out);
        let nl = b.finish();
        for av in [0u64, 1, 3, 0x55, 0xAA, 0xFF, 0x80] {
            for kv in 0..8u64 {
                let o = run1(&nl, &[("a", av), ("k", kv)]);
                assert_eq!(o["o"], (av << kv) & 0xFFFF, "a={av} k={kv}");
            }
        }
    }

    #[test]
    fn lod_and_encoder() {
        let mut b = Builder::new("lod");
        let a = b.input_bus("a", 8);
        let oh = b.leading_one_detector(&a);
        let k = b.onehot_encode(&oh);
        b.output_bus("oh", &oh);
        b.output_bus("k", &k);
        let nl = b.finish();
        for av in 1..256u64 {
            let o = run1(&nl, &[("a", av)]);
            let msb = 63 - av.leading_zeros() as u64;
            assert_eq!(o["oh"], 1 << msb, "a={av}");
            assert_eq!(o["k"], msb, "a={av}");
        }
        // all-zero input
        let o = run1(&nl, &[("a", 0)]);
        assert_eq!(o["oh"], 0);
        assert_eq!(o["k"], 0);
    }

    #[test]
    fn decoder_exhaustive() {
        let mut b = Builder::new("dec");
        let s = b.input_bus("s", 4);
        let d = b.decoder(&s);
        b.output_bus("d", &d);
        let nl = b.finish();
        for sv in 0..16u64 {
            let o = run1(&nl, &[("s", sv)]);
            assert_eq!(o["d"], 1 << sv);
        }
    }

    #[test]
    fn reductions() {
        let mut b = Builder::new("red");
        let a = b.input_bus("a", 5);
        let any = b.or_reduce(&a);
        let all = b.and_reduce(&a);
        b.output_bit("any[0]", any);
        b.output_bit("all[0]", all);
        let nl = b.finish();
        for av in 0..32u64 {
            let o = run1(&nl, &[("a", av)]);
            assert_eq!(o["any"], (av != 0) as u64);
            assert_eq!(o["all"], (av == 31) as u64);
        }
    }

    #[test]
    fn add_extend_widths() {
        let mut b = Builder::new("ax");
        let a = b.input_bus("a", 3);
        let c = b.input_bus("c", 6);
        let s = b.add_extend(&a, &c);
        b.output_bus("s", &s);
        let nl = b.finish();
        for av in 0..8u64 {
            for cv in 0..64u64 {
                let o = run1(&nl, &[("a", av), ("c", cv)]);
                assert_eq!(o["s"], av + cv);
            }
        }
    }
}
