//! Gate-level netlist intermediate representation.
//!
//! Every arithmetic circuit the compiler emits (multipliers, adders,
//! leading-one detectors, barrel shifters, …) is built as a [`Netlist`] of
//! primitive gates. The IR is deliberately simple:
//!
//! * nets are dense `u32` ids; gate inputs always reference *already
//!   created* nets, so creation order is a topological order — evaluation,
//!   timing analysis and power estimation are single forward passes;
//! * evaluation is bit-parallel: each net carries 64 independent samples per
//!   `u64` word, which makes exhaustive 8-bit equivalence checks (65k input
//!   pairs) and switching-activity extraction fast;
//! * the structural view (gate counts by kind) feeds the PPA engine, and the
//!   same structure is what the Verilog emitter prints.

pub mod netlist;
pub mod builder;

pub use builder::Builder;
pub use netlist::{GateKind, Netlist, NetId};
