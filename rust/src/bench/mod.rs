//! Micro-benchmark harness (no criterion offline): warmup + timed iterations
//! with mean / p50 / p99 reporting, plus the fixed-width table printer used
//! by every `benches/table*.rs` target to regenerate the paper's tables.

pub mod harness;

pub use harness::{bench, BenchJson, BenchResult, Table};
