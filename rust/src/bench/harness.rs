//! Timing harness and table printer.
//!
//! `cargo bench` runs each `benches/*.rs` with `harness = false`; those
//! binaries (a) print the reproduced paper table and (b) time the hot paths
//! via [`bench`]. Timing protocol: `warmup` untimed runs, then `iters` timed
//! runs, reporting mean / p50 / p99 / min. A `black_box` is provided to stop
//! the optimizer from deleting the measured work.

use crate::util::stats::percentile;
use std::time::Instant;

/// Prevent the optimizer from eliding a value (stable-Rust friendly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters={:<5} mean={:>10.3}us p50={:>10.3}us p99={:>10.3}us min={:>10.3}us",
            self.name,
            self.iters,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.min_ns / 1e3,
        );
    }
}

/// Run `f` with warmup and timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: percentile(&sorted, 50.0),
        p99_ns: percentile(&sorted, 99.0),
        min_ns: sorted[0],
    };
    r.print();
    r
}

/// Fixed-width ASCII table, used to print the reproduced paper tables in the
/// same row/column layout the paper reports.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = width));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &w));
        let total: usize = w.iter().map(|x| x + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float in engineering style like the paper ("2.82E-04").
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0.00E+00".to_string();
    }
    format!("{:.2E}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || {
            n += 1;
            black_box(n);
        });
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row_str(&["1", "2"]);
        t.row_str(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| 333 | 4    |"));
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(2.82e-4), "2.82E-4");
        assert_eq!(sci(0.0), "0.00E+00");
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }
}
