//! Timing harness and table printer.
//!
//! `cargo bench` runs each `benches/*.rs` with `harness = false`; those
//! binaries (a) print the reproduced paper table and (b) time the hot paths
//! via [`bench`]. Timing protocol: `warmup` untimed runs, then `iters` timed
//! runs, reporting mean / p50 / p99 / min. A `black_box` is provided to stop
//! the optimizer from deleting the measured work.

use crate::util::stats::percentile;
use std::time::Instant;

/// Prevent the optimizer from eliding a value (stable-Rust friendly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    pub fn print(&self) {
        println!(
            "bench {:<40} iters={:<5} mean={:>10.3}us p50={:>10.3}us p99={:>10.3}us min={:>10.3}us",
            self.name,
            self.iters,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p99_ns / 1e3,
            self.min_ns / 1e3,
        );
    }
}

/// Run `f` with warmup and timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: percentile(&sorted, 50.0),
        p99_ns: percentile(&sorted, 99.0),
        min_ns: sorted[0],
    };
    r.print();
    r
}

/// Machine-readable bench emission: collects [`BenchResult`]s plus named
/// speedup ratios and writes them as `BENCH_<name>.json` in the working
/// directory (the package root under `cargo bench`). CI uploads these as
/// artifacts so the perf trajectory is tracked across PRs.
#[derive(Clone, Debug)]
pub struct BenchJson {
    name: String,
    cases: Vec<BenchResult>,
    ratios: Vec<(String, f64)>,
    counters: Vec<(String, f64)>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        BenchJson {
            name: name.to_string(),
            cases: Vec::new(),
            ratios: Vec::new(),
            counters: Vec::new(),
        }
    }

    /// Record one benchmark case.
    pub fn case(&mut self, r: &BenchResult) {
        self.cases.push(r.clone());
    }

    /// Record a named speedup ratio (e.g. `"warm_over_cold"` → 42.0).
    pub fn ratio(&mut self, label: &str, value: f64) {
        self.ratios.push((label.to_string(), value));
    }

    /// Record a named absolute counter (e.g. `"replayed_macs"` → 1.9e8) —
    /// kept in a separate JSON section so ratio consumers never chart raw
    /// counts under ratio semantics.
    pub fn counter(&mut self, label: &str, value: f64) {
        self.counters.push((label.to_string(), value));
    }

    /// Render the JSON document (hand-rolled: the build is offline, no
    /// serde). Non-finite numbers serialize as `null`.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"name\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"cases\": [\n");
        for (i, c) in self.cases.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \"min_ns\": {}}}{}\n",
                json_escape(&c.name),
                c.iters,
                json_num(c.mean_ns),
                json_num(c.p50_ns),
                json_num(c.p99_ns),
                json_num(c.min_ns),
                if i + 1 < self.cases.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"ratios\": {\n");
        for (i, (k, v)) in self.ratios.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(k),
                json_num(*v),
                if i + 1 < self.ratios.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n");
        s.push_str("  \"counters\": {\n");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(k),
                json_num(*v),
                if i + 1 < self.counters.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write `BENCH_<name>.json`; returns the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::PathBuf::from(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Fixed-width ASCII table, used to print the reproduced paper tables in the
/// same row/column layout the paper reports.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = width));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &w));
        let total: usize = w.iter().map(|x| x + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float in engineering style like the paper ("2.82E-04").
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0.00E+00".to_string();
    }
    format!("{:.2E}", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || {
            n += 1;
            black_box(n);
        });
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row_str(&["1", "2"]);
        t.row_str(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| 333 | 4    |"));
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(sci(2.82e-4), "2.82E-4");
        assert_eq!(sci(0.0), "0.00E+00");
    }

    #[test]
    fn bench_json_renders_and_writes() {
        let mut j = BenchJson::new("unit_test");
        j.case(&BenchResult {
            name: "case \"a\"".into(),
            iters: 3,
            mean_ns: 1500.0,
            p50_ns: 1400.0,
            p99_ns: 2000.0,
            min_ns: 1000.0,
        });
        j.ratio("warm_over_cold", 42.5);
        j.ratio("bad", f64::INFINITY);
        j.counter("replayed_macs", 3.0e9);
        let s = j.render();
        assert!(s.contains("\"name\": \"unit_test\""));
        assert!(s.contains("case \\\"a\\\""));
        assert!(s.contains("\"mean_ns\": 1500"));
        assert!(s.contains("\"warm_over_cold\": 42.5"));
        assert!(s.contains("\"bad\": null"));
        assert!(s.contains("\"counters\""));
        assert!(s.contains("\"replayed_macs\": 3000000000"));
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }
}
