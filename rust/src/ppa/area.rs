//! Area model: standard-cell area aggregation plus the placement/routing
//! overhead that turns cell area into placed ("P&R") area.

use crate::gates::Netlist;
use crate::ppa::cells::CellLibrary;

/// Nangate45 DFF_X1 footprint (µm²) — registers are not part of the
/// combinational IR, so PE-level register counts are costed separately.
pub const DFF_AREA_UM2: f64 = 4.522;
/// DFF leakage, nW.
pub const DFF_LEAKAGE_NW: f64 = 65.0;
/// DFF internal + clock-pin energy per clock cycle, fJ (CK toggles twice).
pub const DFF_ENERGY_PER_CYCLE_FJ: f64 = 1.8;

/// Typical standard-cell placement utilization for a small macro —
/// OpenROAD's default floorplans for blocks in this size class place at
/// 60–75%; we use the midpoint and keep it here as a calibration constant.
pub const PLACEMENT_UTILIZATION: f64 = 0.68;

/// Area breakdown of the logic part of a DCiM macro.
#[derive(Clone, Copy, Debug, Default)]
pub struct LogicArea {
    /// Combinational standard-cell area, µm².
    pub comb_um2: f64,
    /// Register (DFF) area, µm².
    pub regs_um2: f64,
    /// Placed area = (comb + regs) / utilization, µm².
    pub placed_um2: f64,
}

/// Sum standard-cell area of a netlist.
pub fn netlist_cell_area_um2(nl: &Netlist, lib: &CellLibrary) -> f64 {
    nl.gates()
        .iter()
        .map(|g| lib.cell(g.kind).area_um2)
        .sum()
}

/// Logic area for a netlist plus `n_dffs` registers.
pub fn logic_area(nl: &Netlist, lib: &CellLibrary, n_dffs: usize) -> LogicArea {
    let comb = netlist_cell_area_um2(nl, lib);
    let regs = n_dffs as f64 * DFF_AREA_UM2;
    LogicArea {
        comb_um2: comb,
        regs_um2: regs,
        placed_um2: (comb + regs) / PLACEMENT_UTILIZATION,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn area_ordering_across_families_16bit() {
        // Table II, 32×16 row ordering: AdderTree > Exact > Appro4-2 ≥ Log.
        let lib = CellLibrary::nangate45();
        let at = netlist_cell_area_um2(&crate::mult::pptree::build_adder_tree(16), &lib);
        let ex = netlist_cell_area_um2(&crate::mult::pptree::build_exact(16), &lib);
        let ap = netlist_cell_area_um2(
            &crate::mult::pptree::build_approx42(
                16,
                crate::config::spec::CompressorKind::Yang1,
                16,
            ),
            &lib,
        );
        let lo = netlist_cell_area_um2(&crate::mult::logarithmic::build_logour(16), &lib);
        assert!(at > ex, "adder-tree {at} <= exact {ex}");
        assert!(ap < ex, "appro {ap} >= exact {ex}");
        assert!(lo < ex, "log {lo} >= exact {ex}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn area_32bit_log_cuts_half() {
        // Table II: Log-our cuts logic area by ~51% at 64×32.
        let lib = CellLibrary::nangate45();
        let ex = netlist_cell_area_um2(&crate::mult::pptree::build_exact(32), &lib);
        let lo = netlist_cell_area_um2(&crate::mult::logarithmic::build_logour(32), &lib);
        let ratio = lo / ex;
        assert!(
            ratio < 0.75,
            "32-bit log/exact area ratio {ratio:.2} not << 1"
        );
    }

    #[test]
    fn placed_area_exceeds_cell_area() {
        let lib = CellLibrary::nangate45();
        let nl = crate::mult::pptree::build_exact(8);
        let la = logic_area(&nl, &lib, 40);
        assert!(la.placed_um2 > la.comb_um2 + la.regs_um2);
        assert!(la.regs_um2 > 100.0); // 40 DFFs
    }

    #[test]
    fn eight_bit_multiplier_area_plausible() {
        // The full 16×8 macro's logic lands near 1 kµm² in Table II; the
        // bare 8-bit multiplier's cell area must be a few hundred µm².
        let lib = CellLibrary::nangate45();
        let a = netlist_cell_area_um2(&crate::mult::pptree::build_exact(8), &lib);
        assert!(a > 100.0 && a < 1500.0, "area {a}");
    }
}
