//! Static timing analysis over a netlist: one topological pass computing
//! per-net arrival times with the load-dependent cell delay model, then the
//! critical path is the max arrival over primary outputs (plus the external
//! load on outputs — the paper's 0.5 pF).

use crate::gates::{GateKind, Netlist};
use crate::ppa::cells::CellLibrary;

/// Timing report for one netlist.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Arrival time per net, ps.
    pub arrival_ps: Vec<f64>,
    /// Critical-path delay to any primary output, ps.
    pub critical_ps: f64,
    /// Name of the critical primary output.
    pub critical_output: String,
}

/// Run STA. `output_load_ff` is the external load on each primary output.
pub fn analyze(nl: &Netlist, lib: &CellLibrary, output_load_ff: f64) -> TimingReport {
    let gates = nl.gates();
    // Collect sink kinds per net for load computation.
    let mut sinks: Vec<Vec<GateKind>> = vec![Vec::new(); gates.len()];
    for g in gates {
        for k in 0..g.kind.arity() {
            sinks[g.inputs[k].idx()].push(g.kind);
        }
    }
    let mut is_output = vec![false; gates.len()];
    for (_, id) in nl.outputs() {
        is_output[id.idx()] = true;
    }
    let mut arrival = vec![0f64; gates.len()];
    for (i, g) in gates.iter().enumerate() {
        let input_arrival = (0..g.kind.arity())
            .map(|k| arrival[g.inputs[k].idx()])
            .fold(0f64, f64::max);
        let load = lib.net_load_ff(&sinks[i], 0.0);
        let mut d = match g.kind {
            GateKind::Input | GateKind::Const0 | GateKind::Const1 => 0.0,
            k => lib.delay_ps(k, load),
        };
        if is_output[i] && output_load_ff > 0.0 {
            // Primary outputs drive the external load through an inserted
            // BUF_X8-class driver (what repair_design does in the flow):
            // intrinsic 30 ps + 0.75 kΩ effective drive.
            d += 30.0 + 0.75 * output_load_ff;
        }
        arrival[i] = input_arrival + d;
    }
    let (critical_output, critical_ps) = nl
        .outputs()
        .iter()
        .map(|(n, id)| (n.clone(), arrival[id.idx()]))
        .fold((String::new(), 0f64), |acc, cur| {
            if cur.1 > acc.1 {
                cur
            } else {
                acc
            }
        });
    TimingReport {
        arrival_ps: arrival,
        critical_ps,
        critical_output,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::Builder;

    #[test]
    fn chain_delay_adds_up() {
        let mut b = Builder::new("chain");
        let x = b.input("x[0]");
        let mut cur = x;
        for _ in 0..10 {
            cur = b.not(cur);
        }
        b.output_bit("y[0]", cur);
        let nl = b.finish();
        let lib = CellLibrary::nangate45();
        let t = analyze(&nl, &lib, 0.0);
        // 10 inverters: last one drives no sinks (just the output); each of
        // the first 9 drives one inverter pin.
        let inv_loaded = lib.delay_ps(crate::gates::GateKind::Not, lib.net_load_ff(&[crate::gates::GateKind::Not], 0.0));
        let inv_unloaded = lib.delay_ps(crate::gates::GateKind::Not, 0.0);
        let expect = 9.0 * inv_loaded + inv_unloaded;
        assert!((t.critical_ps - expect).abs() < 1e-6, "{} vs {expect}", t.critical_ps);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn wider_multipliers_are_slower() {
        let lib = CellLibrary::nangate45();
        let t8 = analyze(&crate::mult::pptree::build_exact(8), &lib, 0.0).critical_ps;
        let t16 = analyze(&crate::mult::pptree::build_exact(16), &lib, 0.0).critical_ps;
        let t32 = analyze(&crate::mult::pptree::build_exact(32), &lib, 0.0).critical_ps;
        assert!(t8 < t16 && t16 < t32);
        // 8-bit multiplier should close timing in a couple of ns at 45 nm.
        assert!(t8 > 200.0 && t8 < 5000.0, "t8 = {t8} ps");
    }

    #[test]
    fn output_load_slows_critical_path() {
        let lib = CellLibrary::nangate45();
        let nl = crate::mult::pptree::build_exact(8);
        let t0 = analyze(&nl, &lib, 0.0).critical_ps;
        let t1 = analyze(&nl, &lib, 500.0).critical_ps; // 0.5 pF
        assert!(t1 > t0);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn log_multiplier_critical_path_comparable_to_exact() {
        // Both must be well under the 5.2 ns SRAM-dominated clock.
        let lib = CellLibrary::nangate45();
        let e = analyze(&crate::mult::pptree::build_exact(16), &lib, 0.0).critical_ps;
        let l = analyze(&crate::mult::logarithmic::build_logour(16), &lib, 0.0).critical_ps;
        assert!(e < 5200.0 && l < 5200.0, "exact {e} logour {l}");
    }
}
