//! FreePDK45-class standard-cell library model.
//!
//! Numbers are calibrated to the Nangate 45 nm Open Cell Library (the
//! library the paper's OpenROAD/FreePDK45 flow maps to): X1 drive cells,
//! 1.1 V, 25 °C, typical corner. Sources: Nangate45 datasheet areas
//! (site 0.19×1.4 µm), typical-corner timing in the 10–40 ps class for
//! X1 drives under FO4-ish loads, and leakage in the tens of nW. These
//! constants are intentionally centralized here — they are the *only*
//! calibration surface of the PPA engine (DESIGN.md §7).

use crate::gates::GateKind;

/// Electrical and physical parameters of one cell.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// Layout area, µm².
    pub area_um2: f64,
    /// Input pin capacitance, fF (per pin).
    pub pin_cap_ff: f64,
    /// Intrinsic (zero-load) delay, ps.
    pub intrinsic_ps: f64,
    /// Drive resistance, kΩ — delay = intrinsic + R · C_load.
    pub drive_kohm: f64,
    /// Leakage power, nW.
    pub leakage_nw: f64,
    /// Internal energy per output toggle, fJ (short-circuit + internal cap).
    pub internal_fj: f64,
}

/// The standard-cell library: one entry per [`GateKind`].
#[derive(Clone, Debug)]
pub struct CellLibrary {
    /// Supply voltage, V.
    pub vdd: f64,
    /// Wire capacitance added per fanout endpoint, fF (wire-load model).
    pub wire_cap_per_fanout_ff: f64,
}

impl Default for CellLibrary {
    fn default() -> Self {
        Self::nangate45()
    }
}

impl CellLibrary {
    /// Nangate45 / FreePDK45 typical corner.
    pub fn nangate45() -> Self {
        Self {
            vdd: 1.1,
            wire_cap_per_fanout_ff: 0.6,
        }
    }

    /// Cell parameters for a gate kind (X1 drives).
    pub fn cell(&self, kind: GateKind) -> Cell {
        // Areas: Nangate45 X1 cells (site = 0.266 µm² per unit width).
        // INV_X1 0.532, NAND2_X1/NOR2_X1 0.798, AND2/OR2 1.064 (NAND+INV),
        // XOR2/XNOR2 1.596, MUX2 1.862.
        match kind {
            GateKind::Const0 | GateKind::Const1 | GateKind::Input => Cell {
                area_um2: 0.0,
                pin_cap_ff: 0.0,
                intrinsic_ps: 0.0,
                drive_kohm: 0.0,
                leakage_nw: 0.0,
                internal_fj: 0.0,
            },
            GateKind::Buf => Cell {
                area_um2: 0.798,
                pin_cap_ff: 1.0,
                intrinsic_ps: 18.0,
                drive_kohm: 5.0,
                leakage_nw: 15.0,
                internal_fj: 0.35,
            },
            GateKind::Not => Cell {
                area_um2: 0.532,
                pin_cap_ff: 1.2,
                intrinsic_ps: 8.0,
                drive_kohm: 6.0,
                leakage_nw: 12.0,
                internal_fj: 0.25,
            },
            GateKind::Nand2 => Cell {
                area_um2: 0.798,
                pin_cap_ff: 1.2,
                intrinsic_ps: 12.0,
                drive_kohm: 7.0,
                leakage_nw: 18.0,
                internal_fj: 0.40,
            },
            GateKind::Nor2 => Cell {
                area_um2: 0.798,
                pin_cap_ff: 1.3,
                intrinsic_ps: 14.0,
                drive_kohm: 8.5,
                leakage_nw: 17.0,
                internal_fj: 0.42,
            },
            GateKind::And2 => Cell {
                area_um2: 1.064,
                pin_cap_ff: 1.1,
                intrinsic_ps: 20.0,
                drive_kohm: 5.5,
                leakage_nw: 25.0,
                internal_fj: 0.55,
            },
            GateKind::Or2 => Cell {
                area_um2: 1.064,
                pin_cap_ff: 1.1,
                intrinsic_ps: 22.0,
                drive_kohm: 5.5,
                leakage_nw: 24.0,
                internal_fj: 0.55,
            },
            GateKind::Xor2 => Cell {
                area_um2: 1.596,
                pin_cap_ff: 1.8,
                intrinsic_ps: 30.0,
                drive_kohm: 6.0,
                leakage_nw: 38.0,
                internal_fj: 0.85,
            },
            GateKind::Xnor2 => Cell {
                area_um2: 1.596,
                pin_cap_ff: 1.8,
                intrinsic_ps: 30.0,
                drive_kohm: 6.0,
                leakage_nw: 38.0,
                internal_fj: 0.85,
            },
            GateKind::Mux2 => Cell {
                area_um2: 1.862,
                pin_cap_ff: 1.4,
                intrinsic_ps: 28.0,
                drive_kohm: 6.5,
                leakage_nw: 42.0,
                internal_fj: 0.80,
            },
        }
    }

    /// Load capacitance seen by a net: sum of sink pin caps + wire cap.
    /// `sink_kinds` are the gate kinds of the fanout pins.
    pub fn net_load_ff(&self, sink_kinds: &[GateKind], extra_load_ff: f64) -> f64 {
        let pins: f64 = sink_kinds.iter().map(|&k| self.cell(k).pin_cap_ff).sum();
        pins + self.wire_cap_per_fanout_ff * sink_kinds.len() as f64 + extra_load_ff
    }

    /// Gate delay driving a given load.
    pub fn delay_ps(&self, kind: GateKind, load_ff: f64) -> f64 {
        let c = self.cell(kind);
        // R[kΩ] × C[fF] → ps  (1 kΩ × 1 fF = 1 ps)
        c.intrinsic_ps + c.drive_kohm * load_ff
    }

    /// Dynamic energy of one output toggle driving `load_ff`:
    /// ½·C·V² (switching) + internal energy.
    pub fn toggle_energy_fj(&self, kind: GateKind, load_ff: f64) -> f64 {
        let c = self.cell(kind);
        0.5 * load_ff * self.vdd * self.vdd + c.internal_fj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverter_is_smallest_logic_cell() {
        let lib = CellLibrary::nangate45();
        let inv = lib.cell(GateKind::Not).area_um2;
        for k in [
            GateKind::And2,
            GateKind::Or2,
            GateKind::Xor2,
            GateKind::Nand2,
            GateKind::Mux2,
        ] {
            assert!(lib.cell(k).area_um2 >= inv);
        }
        assert_eq!(lib.cell(GateKind::Input).area_um2, 0.0);
    }

    #[test]
    fn delay_grows_with_load() {
        let lib = CellLibrary::nangate45();
        let d0 = lib.delay_ps(GateKind::Nand2, 1.0);
        let d1 = lib.delay_ps(GateKind::Nand2, 10.0);
        assert!(d1 > d0);
        // FO4-class delay should be tens of ps, not ns.
        assert!(d0 > 5.0 && d0 < 100.0);
    }

    #[test]
    fn xor_costs_more_than_nand() {
        let lib = CellLibrary::nangate45();
        assert!(lib.cell(GateKind::Xor2).area_um2 > lib.cell(GateKind::Nand2).area_um2);
        assert!(
            lib.toggle_energy_fj(GateKind::Xor2, 2.0)
                > lib.toggle_energy_fj(GateKind::Nand2, 2.0)
        );
    }

    #[test]
    fn net_load_accumulates_pins_and_wire() {
        let lib = CellLibrary::nangate45();
        let l1 = lib.net_load_ff(&[GateKind::Nand2], 0.0);
        let l4 = lib.net_load_ff(&[GateKind::Nand2; 4], 0.0);
        assert!(l4 > 3.0 * l1);
        let ext = lib.net_load_ff(&[], 500.0); // 0.5 pF output pad
        assert_eq!(ext, 500.0);
    }
}
