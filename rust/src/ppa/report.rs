//! Table II assembly: full post-"layout" PPA of one SRAM-multiplier system.
//!
//! Methodology mirrors the paper's §V-A: every multiplier variant of a
//! given size is driven with the *same* multiplication workload (seeded
//! random operand stream through the PE), power comes from switching
//! activity, the critical delay is SRAM-dominated, and "P&R" area is the
//! logic + SRAM total.

use crate::config::spec::MacroSpec;
use crate::gates::Netlist;
use crate::pe::buffers;
use crate::pe::control::build_fsm_logic;
use crate::ppa::area::{self, DFF_ENERGY_PER_CYCLE_FJ, DFF_LEAKAGE_NW};
use crate::ppa::cells::CellLibrary;
use crate::ppa::{power, timing};
use crate::sim::activity::{activity_parallel, mult_workload_vectors, ActivityReport};
use crate::sram::models as sram_models;
use crate::store::{
    ActivityStats, DesignPointRecord, DesignPointStore, Key128, KeyBuilder, PpaSummary,
};
use crate::util::rng::Pcg32;

/// One Table II row.
#[derive(Clone, Debug)]
pub struct MacroPpa {
    pub name: String,
    pub family_label: String,
    /// System critical delay, ns (max of SRAM access and logic path).
    pub delay_ns: f64,
    /// Logic area (multiplier + PE control + buffers), placed, µm².
    pub logic_area_um2: f64,
    /// SRAM macro area, µm².
    pub sram_area_um2: f64,
    /// P&R (total) area, µm².
    pub pnr_area_um2: f64,
    /// Total power at the target clock, W.
    pub power_w: f64,
    /// Energy per multiply, J.
    pub energy_per_op_j: f64,
    /// Logic-only dynamic+leakage power, W (for family comparisons).
    pub logic_power_w: f64,
    /// Gate count of the multiplier netlist.
    pub mult_gates: usize,
}

/// Analyze one macro spec under a seeded random workload of `n_ops`
/// multiplications. The same `seed` across families gives the identical
/// operand stream the paper's comparison requires.
///
/// Single-threaded — the right default for nested callers (the DSE sweep
/// already runs one design point per worker). Top-level callers with the
/// cores to spare should use [`analyze_macro_threads`].
pub fn analyze_macro(spec: &MacroSpec, n_ops: usize, seed: u64) -> MacroPpa {
    analyze_macro_threads(spec, n_ops, seed, 1)
}

/// [`analyze_macro`] with the activity stream split across `threads`
/// workers (bit-identical results for any thread count; see
/// [`activity_parallel`]).
pub fn analyze_macro_threads(spec: &MacroSpec, n_ops: usize, seed: u64, threads: usize) -> MacroPpa {
    let mult_nl = crate::mult::build_netlist(&spec.mult);
    analyze_with_netlist(spec, &mult_nl, n_ops, seed, threads).0
}

/// [`analyze_macro_threads`] consulting the design-point store first. The
/// key covers everything the result depends on — the multiplier netlist
/// structure, the full SRAM organization + timing knobs, clock, load and
/// the workload `(n_ops, seed)` — but *not* the instance name, so two
/// specs naming the same design share one record. On a miss the full
/// analysis runs and the record (PPA summary + per-net toggle activity)
/// is written back.
pub fn analyze_macro_cached(
    spec: &MacroSpec,
    n_ops: usize,
    seed: u64,
    threads: usize,
    store: Option<&DesignPointStore>,
) -> MacroPpa {
    let Some(store) = store else {
        return analyze_macro_threads(spec, n_ops, seed, threads);
    };
    let mult_nl = crate::mult::build_netlist(&spec.mult);
    let key = ppa_key(spec, &mult_nl, n_ops, seed);
    let (rec, _hit) = store.get_or_put_with(key, || {
        let (ppa, act) = analyze_with_netlist(spec, &mult_nl, n_ops, seed, threads);
        DesignPointRecord {
            family: spec.mult.family.name(),
            bits: spec.mult.bits as u32,
            rows: spec.sram.rows as u32,
            n_ops: n_ops as u64,
            seed,
            ppa: Some(PpaSummary::from_ppa(&ppa)),
            activity: Some(ActivityStats::from_report(&act)),
            ..Default::default()
        }
    });
    match rec.ppa {
        Some(p) => p.to_ppa(&spec.name, spec.mult.family.paper_label()),
        None => analyze_with_netlist(spec, &mult_nl, n_ops, seed, threads).0,
    }
}

fn ppa_key(spec: &MacroSpec, mult_nl: &Netlist, n_ops: usize, seed: u64) -> Key128 {
    let s = &spec.sram;
    KeyBuilder::new("ppa/1")
        .netlist(mult_nl)
        .u32(spec.mult.bits as u32)
        .u8(spec.mult.signed as u8)
        .u32(s.rows as u32)
        .u32(s.word_bits as u32)
        .u32(s.banks as u32)
        .u32(s.subarrays as u32)
        .u32(s.mux_ratio as u32)
        .f64(s.timing.sae_delay_ps)
        .f64(s.timing.precharge_ps)
        .f64(s.timing.wl_pulse_ps)
        .f64(spec.clock_mhz)
        .f64(spec.load_pf)
        .u64(n_ops as u64)
        .u64(seed)
        .finish()
}

fn analyze_with_netlist(
    spec: &MacroSpec,
    mult_nl: &Netlist,
    n_ops: usize,
    seed: u64,
    threads: usize,
) -> (MacroPpa, ActivityReport) {
    spec.validate().expect("spec must validate");
    let lib = CellLibrary::nangate45();
    let clock_hz = spec.clock_mhz * 1e6;
    let load_ff = spec.load_pf * 1000.0;

    // --- netlists: control FSM logic (multiplier supplied by caller) ---
    let fsm_nl = build_fsm_logic();

    // --- workload: same operand stream for every family at this size ---
    let mut rng = Pcg32::new(seed);
    let mask = (1u64 << spec.mult.bits) - 1;
    let pairs: Vec<(u64, u64)> = (0..n_ops)
        .map(|_| (rng.next_u64() & mask, rng.next_u64() & mask))
        .collect();
    let vectors = mult_workload_vectors(spec.mult.bits, &pairs);
    let act = activity_parallel(mult_nl, &vectors, threads);

    // --- logic power ---
    let mult_power = power::analyze(mult_nl, &lib, &act, clock_hz, load_ff);
    let regs = buffers::budget(spec);
    let reg_power_w = regs.total() as f64
        * (DFF_ENERGY_PER_CYCLE_FJ * 1e-15 * clock_hz + DFF_LEAKAGE_NW * 1e-9);
    // FSM logic power: tiny; cost it at a pessimistic α = 0.2.
    let fsm_area = area::netlist_cell_area_um2(&fsm_nl, &lib);
    let fsm_power_w = fsm_area * 0.05e-6; // ~0.05 µW/µm² at 100 MHz, α≈0.2
    let logic_power_w = mult_power.total_w() + reg_power_w + fsm_power_w;

    // --- areas ---
    let logic = area::logic_area(mult_nl, &lib, regs.total());
    let logic_area_um2 = logic.placed_um2 + fsm_area / area::PLACEMENT_UTILIZATION;
    let sram_area_um2 = sram_models::area(&spec.sram).total_um2;

    // --- timing ---
    let sram_t = sram_models::timing(&spec.sram, None);
    let logic_t = timing::analyze(mult_nl, &lib, load_ff);
    let delay_ns = sram_t.access_ns.max(logic_t.critical_ps / 1000.0);

    // --- SRAM power (one read per multiply) ---
    let sram_p = sram_models::power(&spec.sram, clock_hz);

    let power_w = logic_power_w + sram_p.total_w();
    let ppa = MacroPpa {
        name: spec.name.clone(),
        family_label: spec.mult.family.paper_label().to_string(),
        delay_ns,
        logic_area_um2,
        sram_area_um2,
        pnr_area_um2: logic_area_um2 + sram_area_um2,
        power_w,
        energy_per_op_j: power_w / clock_hz,
        logic_power_w,
        mult_gates: mult_nl.logic_gate_count(),
    };
    (ppa, act)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{MacroSpec, MultFamily};

    fn row(rows: usize, bits: usize, fam: MultFamily) -> MacroPpa {
        let spec = MacroSpec::new("t", rows, bits, fam);
        analyze_macro(&spec, 1500, 0x7AB1E2)
    }

    #[test]
    fn delay_is_sram_dominated_and_constant_across_families() {
        let e = row(16, 8, MultFamily::Exact);
        let l = row(16, 8, MultFamily::LogOur);
        let a = row(16, 8, MultFamily::default_approx(8));
        assert!((e.delay_ns - l.delay_ns).abs() < 1e-9);
        assert!((e.delay_ns - a.delay_ns).abs() < 1e-9);
        assert!((4.8..5.8).contains(&e.delay_ns), "delay {}", e.delay_ns);
    }

    #[test]
    fn cached_analysis_is_bit_identical_and_name_independent() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_ppa_cache_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let store = DesignPointStore::open(&dir).unwrap();
        let spec = MacroSpec::new("t_cached", 16, 8, MultFamily::default_approx(8));
        let fresh = analyze_macro(&spec, 300, 0x7AB1E2);
        let miss = analyze_macro_cached(&spec, 300, 0x7AB1E2, 1, Some(&store));
        let hit = analyze_macro_cached(&spec, 300, 0x7AB1E2, 1, Some(&store));
        for r in [&miss, &hit] {
            assert_eq!(r.power_w.to_bits(), fresh.power_w.to_bits());
            assert_eq!(r.energy_per_op_j.to_bits(), fresh.energy_per_op_j.to_bits());
            assert_eq!(r.logic_area_um2.to_bits(), fresh.logic_area_um2.to_bits());
            assert_eq!(r.delay_ns.to_bits(), fresh.delay_ns.to_bits());
            assert_eq!(r.mult_gates, fresh.mult_gates);
        }
        // Content addressing: a different instance name maps to the SAME
        // record (the name is reattached on the way out).
        let renamed = MacroSpec::new("other_name", 16, 8, MultFamily::default_approx(8));
        let r = analyze_macro_cached(&renamed, 300, 0x7AB1E2, 1, Some(&store));
        assert_eq!(r.name, "other_name");
        assert_eq!(r.power_w.to_bits(), fresh.power_w.to_bits());
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes), (2, 1, 1));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pnr_is_logic_plus_sram() {
        let r = row(32, 16, MultFamily::Exact);
        assert!((r.pnr_area_um2 - (r.logic_area_um2 + r.sram_area_um2)).abs() < 1e-9);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn family_ordering_16bit_matches_table2() {
        // 32×16 row: OpenC2 > Exact > Appro4-2, Log-our < Appro4-2 (paper:
        // log 2402 < appro 2633 < exact 3568 < openc2 4842).
        let oc = row(32, 16, MultFamily::AdderTree);
        let ex = row(32, 16, MultFamily::Exact);
        let ap = row(32, 16, MultFamily::table2_approx(16));
        let lo = row(32, 16, MultFamily::LogOur);
        assert!(oc.logic_area_um2 > ex.logic_area_um2);
        assert!(ap.logic_area_um2 < ex.logic_area_um2);
        assert!(lo.logic_area_um2 < ex.logic_area_um2);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn power_ordering_32bit_log_wins_big() {
        // 64×32: Log-our ~64% below exact (logic-dominated).
        let ex = row(64, 32, MultFamily::Exact);
        let lo = row(64, 32, MultFamily::LogOur);
        let ap = row(64, 32, MultFamily::table2_approx(32));
        assert!(lo.power_w < ex.power_w);
        assert!(ap.power_w < ex.power_w);
        assert!(lo.power_w < ap.power_w, "log must beat appro4-2 at 32 bit");
        let saving = 1.0 - lo.logic_power_w / ex.logic_power_w;
        assert!(
            saving > 0.35,
            "32-bit log logic-power saving only {:.0}%",
            saving * 100.0
        );
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn power_magnitudes_in_paper_decade() {
        // Table II totals: 1e-4 … 7e-3 W.
        for (rows, bits) in [(16, 8), (32, 16), (64, 32)] {
            let r = row(rows, bits, MultFamily::Exact);
            assert!(
                (1e-5..2e-2).contains(&r.power_w),
                "{rows}x{bits} power {}",
                r.power_w
            );
        }
    }

    #[test]
    fn appro42_beats_exact_at_8bit_power() {
        // Table II 16×8: Appro4-2 2.11E-4 < Exact 2.45E-4.
        let ex = row(16, 8, MultFamily::Exact);
        let ap = row(16, 8, MultFamily::default_approx(8));
        assert!(ap.logic_power_w < ex.logic_power_w);
    }
}
