//! Power model: dynamic power from simulated switching activity plus
//! leakage.
//!
//! `P_dyn = Σ_net toggles(net)/transitions · E_toggle(net) · f_clk` where
//! `E_toggle = ½·C_load·V² + E_internal`. The switching activity comes from
//! the gate simulator running the *same multiplication workload* on every
//! multiplier variant, which is exactly the paper's methodology ("all
//! designs are evaluated using the same multiplication workloads").

use crate::gates::{GateKind, Netlist};
use crate::ppa::cells::CellLibrary;
use crate::sim::activity::ActivityReport;

/// Power breakdown, W.
#[derive(Clone, Copy, Debug, Default)]
pub struct PowerReport {
    pub dynamic_w: f64,
    pub leakage_w: f64,
}

impl PowerReport {
    pub fn total_w(&self) -> f64 {
        self.dynamic_w + self.leakage_w
    }
}

/// Compute power for a netlist given its activity under a workload.
///
/// * `clock_hz` — vector rate (one multiplication per cycle);
/// * `output_load_ff` — external load on primary outputs.
pub fn analyze(
    nl: &Netlist,
    lib: &CellLibrary,
    activity: &ActivityReport,
    clock_hz: f64,
    output_load_ff: f64,
) -> PowerReport {
    let gates = nl.gates();
    assert_eq!(activity.toggles.len(), gates.len());
    let mut sinks: Vec<Vec<GateKind>> = vec![Vec::new(); gates.len()];
    for g in gates {
        for k in 0..g.kind.arity() {
            sinks[g.inputs[k].idx()].push(g.kind);
        }
    }
    let mut is_output = vec![false; gates.len()];
    for (_, id) in nl.outputs() {
        is_output[id.idx()] = true;
    }
    let transitions = activity.transitions.max(1) as f64;
    let mut dyn_fj_per_cycle = 0f64;
    let mut leak_nw = 0f64;
    for (i, g) in gates.iter().enumerate() {
        let cell = lib.cell(g.kind);
        leak_nw += cell.leakage_nw;
        if matches!(
            g.kind,
            GateKind::Input | GateKind::Const0 | GateKind::Const1
        ) {
            continue;
        }
        let extra = if is_output[i] { output_load_ff } else { 0.0 };
        let load = lib.net_load_ff(&sinks[i], extra);
        let alpha = activity.toggles[i] as f64 / transitions;
        dyn_fj_per_cycle += alpha * lib.toggle_energy_fj(g.kind, load);
    }
    PowerReport {
        // fJ/cycle × cycles/s → fW → W
        dynamic_w: dyn_fj_per_cycle * clock_hz * 1e-15,
        leakage_w: leak_nw * 1e-9,
    }
}

/// Energy per operation (J/op) — the headline metric for the
/// accuracy-energy trade-off figure.
pub fn energy_per_op_j(report: &PowerReport, clock_hz: f64) -> f64 {
    report.total_w() / clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::{activity_bitparallel, mult_workload_vectors};
    use crate::util::rng::Pcg32;

    fn random_workload(bits: usize, n: usize, seed: u64) -> Vec<(u64, u64)> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                (
                    rng.below(1 << bits) as u64,
                    rng.below(1 << bits) as u64,
                )
            })
            .collect()
    }

    #[test]
    fn idle_workload_is_leakage_only() {
        let nl = crate::mult::pptree::build_exact(8);
        let lib = CellLibrary::nangate45();
        let vectors = mult_workload_vectors(8, &[(0, 0); 100]);
        let act = activity_bitparallel(&nl, &vectors);
        let p = analyze(&nl, &lib, &act, 100e6, 0.0);
        assert_eq!(p.dynamic_w, 0.0);
        assert!(p.leakage_w > 0.0);
    }

    #[test]
    fn power_scales_with_frequency() {
        let nl = crate::mult::pptree::build_exact(8);
        let lib = CellLibrary::nangate45();
        let act = activity_bitparallel(
            &nl,
            &mult_workload_vectors(8, &random_workload(8, 500, 1)),
        );
        let p100 = analyze(&nl, &lib, &act, 100e6, 0.0);
        let p200 = analyze(&nl, &lib, &act, 200e6, 0.0);
        assert!((p200.dynamic_w / p100.dynamic_w - 2.0).abs() < 1e-9);
        assert_eq!(p200.leakage_w, p100.leakage_w);
    }

    #[test]
    fn approx_multiplier_uses_less_power_than_exact() {
        // The Table II premise at the logic level: same workload, fewer
        // gates and toggles → less power.
        let lib = CellLibrary::nangate45();
        let wl = random_workload(8, 2000, 2);
        let vex = mult_workload_vectors(8, &wl);
        let exact = crate::mult::pptree::build_exact(8);
        let appro = crate::mult::pptree::build_approx42(
            8,
            crate::config::spec::CompressorKind::Yang1,
            8,
        );
        let p_ex = analyze(
            &exact,
            &lib,
            &activity_bitparallel(&exact, &vex),
            100e6,
            500.0,
        );
        let p_ap = analyze(
            &appro,
            &lib,
            &activity_bitparallel(&appro, &vex),
            100e6,
            500.0,
        );
        assert!(
            p_ap.total_w() < p_ex.total_w(),
            "appro {} >= exact {}",
            p_ap.total_w(),
            p_ex.total_w()
        );
    }

    #[test]
    fn power_magnitude_is_plausible_for_45nm() {
        // An 8-bit multiplier at 100 MHz should burn µW-to-low-mW, not W.
        let lib = CellLibrary::nangate45();
        let nl = crate::mult::pptree::build_exact(8);
        let act = activity_bitparallel(
            &nl,
            &mult_workload_vectors(8, &random_workload(8, 2000, 3)),
        );
        let p = analyze(&nl, &lib, &act, 100e6, 500.0);
        let w = p.total_w();
        assert!(w > 1e-6 && w < 5e-3, "power {w} W out of plausible range");
    }
}
