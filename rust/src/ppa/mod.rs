//! PPA engine: the substitution for OpenROAD + OpenSTA + FreePDK45 signoff
//! (see DESIGN.md §3).
//!
//! * [`cells`] — a FreePDK45(Nangate45)-class standard-cell model: area,
//!   pin capacitance, intrinsic delay, drive resistance and leakage per
//!   gate kind. All calibration constants live there.
//! * [`timing`] — topological static timing analysis with a load-dependent
//!   linear delay model; reports the critical path.
//! * [`power`] — dynamic power from simulated switching activity
//!   (P = α·C·V²·f) plus state-independent leakage.
//! * [`area`] — cell area plus a placement-density/routing overhead factor
//!   (the "P&R" column of Table II).
//! * [`report`] — assembles the Table II row for one macro spec:
//!   delay (SRAM access dominated), logic/SRAM/P&R area, total power.

pub mod cells;
pub mod timing;
pub mod power;
pub mod area;
pub mod report;
pub mod cli;

pub use cells::CellLibrary;
pub use report::{analyze_macro, analyze_macro_cached, analyze_macro_threads, MacroPpa};
