//! `openacm ppa` — print Table II rows for one or all configurations.

use anyhow::Result;

use super::report::{analyze_macro, analyze_macro_threads, MacroPpa};
use crate::bench::harness::{sci, Table};
use crate::config::spec::{MacroSpec, MultFamily};
use crate::util::cli::Args;
use crate::util::threadpool::ThreadPool;

/// Parse a multiplier family from CLI-ish strings.
pub fn parse_family(s: &str, _bits: usize, compressor: &str, approx_cols: usize) -> Result<MultFamily> {
    Ok(match s {
        "exact" => MultFamily::Exact,
        "logour" | "log-our" => MultFamily::LogOur,
        "mitchell" | "lm" => MultFamily::Mitchell,
        "adder_tree" | "openc2" => MultFamily::AdderTree,
        "appro42" | "approx42" => MultFamily::Approx42 {
            compressor: crate::config::spec::CompressorKind::parse(compressor)?,
            approx_cols,
        },
        other => anyhow::bail!("unknown multiplier family {other:?}"),
    })
}

/// Compute the full Table II (3 sizes × 4 families). Top-level entry, so
/// each row's activity extraction spreads across `threads` cores.
pub fn full_table2(n_ops: usize, seed: u64, threads: usize) -> Vec<MacroPpa> {
    let mut rows = Vec::new();
    for (r, b) in [(16usize, 8usize), (32, 16), (64, 32)] {
        for fam in MacroSpec::table2_families(b) {
            let spec = MacroSpec::new(&format!("dcim{r}x{b}"), r, b, fam);
            rows.push(analyze_macro_threads(&spec, n_ops, seed, threads));
        }
    }
    rows
}

/// Render Table II in the paper's layout.
pub fn render_table2(rows: &[MacroPpa]) -> Table {
    let mut t = Table::new(
        "Table II: post-layout PPA of SRAM-multiplier systems (100 MHz, 0.5 pF)",
        &[
            "SRAM", "Multiplier", "Delay (ns)", "Logic (um2)", "SRAM (um2)", "P&R (um2)",
            "Power (W)",
        ],
    );
    for r in rows {
        t.row(&[
            r.name.clone(),
            r.family_label.clone(),
            format!("{:.2}", r.delay_ns),
            format!("{:.0}", r.logic_area_um2),
            format!("{:.0}", r.sram_area_um2),
            format!("{:.0}", r.pnr_area_um2),
            sci(r.power_w),
        ]);
    }
    t
}

pub fn cmd_ppa(args: &Args) -> Result<()> {
    let n_ops = args.usize_or("ops", 2000)?;
    let seed = args.u64_or("seed", 0x7AB1E2)?;
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    match args.get("rows") {
        None => {
            // Full table.
            let rows = full_table2(n_ops, seed, threads);
            render_table2(&rows).print();
        }
        Some(r) => {
            let rows: usize = r.parse()?;
            let bits = args.usize_or("word-bits", 8)?;
            let fam = parse_family(
                args.str_or("mult", "exact"),
                bits,
                args.str_or("compressor", "yang1"),
                args.usize_or("approx-cols", bits)?,
            )?;
            let spec = MacroSpec::new(&format!("dcim{rows}x{bits}"), rows, bits, fam);
            let row = analyze_macro_threads(&spec, n_ops, seed, threads);
            render_table2(&[row]).print();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_parsing() {
        assert_eq!(parse_family("exact", 8, "yang1", 8).unwrap(), MultFamily::Exact);
        assert!(matches!(
            parse_family("appro42", 8, "kong", 6).unwrap(),
            MultFamily::Approx42 { approx_cols: 6, .. }
        ));
        assert!(parse_family("nope", 8, "yang1", 8).is_err());
    }

    #[test]
    fn table_render_smoke() {
        let spec = MacroSpec::new("dcim16x8", 16, 8, MultFamily::Exact);
        let row = analyze_macro(&spec, 200, 1);
        let s = render_table2(&[row]).render();
        assert!(s.contains("dcim16x8"));
        assert!(s.contains("Exact"));
    }
}
