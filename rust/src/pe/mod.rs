//! PE (processing element) compiler — paper §III-A component 1.
//!
//! The PE wraps one SRAM macro and one multiplier: it first initializes the
//! SRAM with stored operands (weights), then streams input operands against
//! stored rows, producing products (and optionally accumulating). This
//! module provides:
//!
//! * [`control`] — the control FSM's combinational next-state/output logic
//!   as a gate netlist plus its register budget (the sequential state is
//!   costed as DFFs by the PPA engine and emitted by the Verilog writer);
//! * [`buffers`] — input/output buffer sizing;
//! * [`integrate`] — the cycle-level behavioral PE used by the examples,
//!   the Table II workload generator and the serving coordinator's energy
//!   accounting.

pub mod control;
pub mod buffers;
pub mod integrate;

pub use buffers::RegisterBudget;
pub use integrate::ProcessingElement;
