//! PE control FSM: IDLE → LOAD (SRAM init) → COMPUTE (stream) → DRAIN.
//!
//! The *combinational* next-state / output logic is generated as a gate
//! netlist (so it participates in PPA and Verilog emission); the two state
//! flops plus the address counter are part of the register budget.

use crate::gates::{Builder, Netlist};

/// FSM state encoding (2 bits).
pub const IDLE: u64 = 0b00;
pub const LOAD: u64 = 0b01;
pub const COMPUTE: u64 = 0b10;
pub const DRAIN: u64 = 0b11;

/// Generate the next-state and output logic netlist.
///
/// Inputs: `state[1:0]`, `start`, `last_row` (address counter terminal),
/// `in_valid`. Outputs: `next[1:0]`, `sram_we`, `sram_ce`, `addr_en`,
/// `out_valid`.
pub fn build_fsm_logic() -> Netlist {
    let mut b = Builder::new("pe_ctrl_fsm");
    let s0 = b.input("state[0]");
    let s1 = b.input("state[1]");
    let start = b.input("start[0]");
    let last = b.input("last_row[0]");
    let in_valid = b.input("in_valid[0]");

    let ns0_ = b.not(s0);
    let ns1_ = b.not(s1);
    let is_idle = b.and(ns1_, ns0_);
    let is_load = b.and(ns1_, s0);
    let is_compute = b.and(s1, ns0_);
    let is_drain = b.and(s1, s0);

    // next state:
    //   IDLE   -> start ? LOAD : IDLE
    //   LOAD   -> last  ? COMPUTE : LOAD
    //   COMPUTE-> last  ? DRAIN : COMPUTE
    //   DRAIN  -> IDLE
    let nlast = b.not(last);
    // next[0] = (IDLE & start) | (LOAD & !last)           — states 01
    let t_idle_start = b.and(is_idle, start);
    let t_load_stay = b.and(is_load, nlast);
    let next0_a = b.or(t_idle_start, t_load_stay);
    // DRAIN bit0 of next (-> IDLE = 00) contributes nothing.
    // next[0] |= (COMPUTE & last) (-> DRAIN = 11)
    let t_comp_done = b.and(is_compute, last);
    let next0 = b.or(next0_a, t_comp_done);
    // next[1] = (LOAD & last) | (COMPUTE & !last) | (COMPUTE & last)
    //         = (LOAD & last) | COMPUTE
    let t_load_done = b.and(is_load, last);
    let next1 = b.or(t_load_done, is_compute);

    // outputs
    let sram_we = b.and(is_load, in_valid);
    let ce_cl = b.or(is_load, is_compute);
    let sram_ce = ce_cl;
    let addr_en_c = b.or(is_load, is_compute);
    let addr_en = b.and(addr_en_c, in_valid);
    let out_valid = b.and(is_compute, in_valid);
    let busy = b.or3(is_load, is_compute, is_drain);

    b.output_bit("next[0]", next0);
    b.output_bit("next[1]", next1);
    b.output_bit("sram_we[0]", sram_we);
    b.output_bit("sram_ce[0]", sram_ce);
    b.output_bit("addr_en[0]", addr_en);
    b.output_bit("out_valid[0]", out_valid);
    b.output_bit("busy[0]", busy);
    let nl = b.finish();
    nl.validate().expect("fsm netlist");
    nl
}

/// Software reference of the same FSM (used by tests and the behavioral PE).
pub fn next_state(state: u64, start: bool, last_row: bool) -> u64 {
    match state {
        IDLE => {
            if start {
                LOAD
            } else {
                IDLE
            }
        }
        LOAD => {
            if last_row {
                COMPUTE
            } else {
                LOAD
            }
        }
        COMPUTE => {
            if last_row {
                DRAIN
            } else {
                COMPUTE
            }
        }
        DRAIN => IDLE,
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn netlist_matches_reference_fsm_exhaustively() {
        let nl = build_fsm_logic();
        for state in [IDLE, LOAD, COMPUTE, DRAIN] {
            for start in [false, true] {
                for last in [false, true] {
                    for valid in [false, true] {
                        let mut ops = BTreeMap::new();
                        ops.insert("state".to_string(), state);
                        ops.insert("start".to_string(), start as u64);
                        ops.insert("last_row".to_string(), last as u64);
                        ops.insert("in_valid".to_string(), valid as u64);
                        let out = nl.eval_uint(&ops);
                        assert_eq!(
                            out["next"],
                            next_state(state, start, last),
                            "state={state} start={start} last={last}"
                        );
                        // we only during LOAD with valid data
                        assert_eq!(
                            out["sram_we"] == 1,
                            state == LOAD && valid,
                            "we @ {state}"
                        );
                        assert_eq!(out["out_valid"] == 1, state == COMPUTE && valid);
                        assert_eq!(out["busy"] == 1, state != IDLE);
                    }
                }
            }
        }
    }

    #[test]
    fn fsm_cycle_walkthrough() {
        // IDLE -start-> LOAD (xN) -last-> COMPUTE (xN) -last-> DRAIN -> IDLE
        let mut s = IDLE;
        s = next_state(s, true, false);
        assert_eq!(s, LOAD);
        s = next_state(s, false, false);
        assert_eq!(s, LOAD);
        s = next_state(s, false, true);
        assert_eq!(s, COMPUTE);
        s = next_state(s, false, true);
        assert_eq!(s, DRAIN);
        s = next_state(s, false, false);
        assert_eq!(s, IDLE);
    }
}
