//! Input/output buffer sizing: the PE's register budget, costed as DFFs by
//! the PPA engine and emitted by the Verilog writer.

use crate::config::spec::MacroSpec;

/// Register counts for one PE.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegisterBudget {
    /// Input operand buffer (one word).
    pub input_regs: usize,
    /// Output product buffer (double width).
    pub output_regs: usize,
    /// Address counter.
    pub addr_regs: usize,
    /// FSM state + handshake flops.
    pub ctrl_regs: usize,
}

impl RegisterBudget {
    pub fn total(&self) -> usize {
        self.input_regs + self.output_regs + self.addr_regs + self.ctrl_regs
    }
}

/// Size the buffers for a macro spec.
pub fn budget(spec: &MacroSpec) -> RegisterBudget {
    let addr_bits = (usize::BITS - (spec.sram.rows - 1).leading_zeros()) as usize;
    RegisterBudget {
        input_regs: spec.mult.bits,
        output_regs: 2 * spec.mult.bits,
        addr_regs: addr_bits,
        // 2 FSM bits + start/valid/ready synchronizers.
        ctrl_regs: 2 + 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{MacroSpec, MultFamily};

    #[test]
    fn budget_for_paper_configs() {
        let spec = MacroSpec::new("x", 16, 8, MultFamily::Exact);
        let b = budget(&spec);
        assert_eq!(b.input_regs, 8);
        assert_eq!(b.output_regs, 16);
        assert_eq!(b.addr_regs, 4);
        assert_eq!(b.total(), 8 + 16 + 4 + 6);

        let spec32 = MacroSpec::new("y", 64, 32, MultFamily::Exact);
        let b32 = budget(&spec32);
        assert_eq!(b32.addr_regs, 6);
        assert_eq!(b32.output_regs, 64);
    }
}
