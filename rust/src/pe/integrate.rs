//! Behavioral PE: SRAM macro + behavioral multiplier + control sequencing.
//!
//! Models the paper's PE operation (§III-A): initialize the SRAM with
//! stored operands, then stream inputs; each cycle reads a row and
//! multiplies it with the incoming operand. A MAC mode accumulates across
//! rows (the CiM dot-product primitive used by the NN workloads).

use anyhow::Result;

use super::control;
use crate::config::spec::MacroSpec;
use crate::mult;
use crate::sram::macro_gen::SramMacro;

/// Cycle-accurate-ish behavioral PE.
pub struct ProcessingElement {
    pub spec: MacroSpec,
    sram: SramMacro,
    mult_fn: Box<dyn Fn(u64, u64) -> u64 + Send + Sync>,
    state: u64,
    /// Cycles spent per FSM state (energy/throughput accounting).
    pub cycles: u64,
    pub mults_done: u64,
}

impl ProcessingElement {
    pub fn new(spec: &MacroSpec) -> Result<Self> {
        spec.validate()?;
        let sram = SramMacro::generate(&spec.sram)?;
        let mult_fn = mult::behavioral(&spec.mult.family, spec.mult.bits);
        Ok(Self {
            spec: spec.clone(),
            sram,
            mult_fn,
            state: control::IDLE,
            cycles: 0,
            mults_done: 0,
        })
    }

    /// LOAD phase: store operand words (weights) into the SRAM.
    pub fn load_weights(&mut self, weights: &[u64]) -> Result<()> {
        self.state = control::next_state(self.state, true, false);
        assert_eq!(self.state, control::LOAD);
        for (i, &w) in weights.iter().enumerate() {
            self.sram.write(i, w)?;
            let last = i + 1 == weights.len();
            self.cycles += 1;
            self.state = control::next_state(self.state, false, last);
        }
        assert_eq!(self.state, control::COMPUTE);
        Ok(())
    }

    /// COMPUTE phase: one input against one stored row → product.
    pub fn compute(&mut self, row: usize, input: u64) -> Result<u64> {
        assert_eq!(self.state, control::COMPUTE, "PE must be in COMPUTE");
        let w = self.sram.read(row)?;
        self.cycles += 1;
        self.mults_done += 1;
        Ok((self.mult_fn)(input, w))
    }

    /// Dot product of the input vector against stored rows `0..inputs.len()`
    /// (the CiM MAC primitive). Accumulates in u128 to avoid overflow.
    pub fn dot(&mut self, inputs: &[u64]) -> Result<u128> {
        let mut acc: u128 = 0;
        for (row, &x) in inputs.iter().enumerate() {
            acc += self.compute(row, x)? as u128;
        }
        Ok(acc)
    }

    /// Finish: DRAIN back to IDLE.
    pub fn finish(&mut self) {
        self.state = control::next_state(self.state, false, true);
        self.state = control::next_state(self.state, false, false);
        assert_eq!(self.state, control::IDLE);
    }

    /// Access counts for energy accounting.
    pub fn sram_reads(&self) -> u64 {
        self.sram.reads
    }

    pub fn sram_writes(&self) -> u64 {
        self.sram.writes
    }

    /// Generate the (input, stored) pairs a workload produces — used to
    /// drive the gate-level activity simulation with the *same* operand
    /// stream the PE saw (Table II methodology).
    pub fn workload_pairs(weights: &[u64], inputs: &[u64]) -> Vec<(u64, u64)> {
        inputs
            .iter()
            .flat_map(|&x| weights.iter().map(move |&w| (x, w)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{MacroSpec, MultFamily};

    fn pe(family: MultFamily) -> ProcessingElement {
        ProcessingElement::new(&MacroSpec::new("t", 16, 8, family)).unwrap()
    }

    #[test]
    fn exact_pe_computes_products() {
        let mut p = pe(MultFamily::Exact);
        p.load_weights(&[3, 5, 7, 9]).unwrap();
        assert_eq!(p.compute(0, 10).unwrap(), 30);
        assert_eq!(p.compute(3, 11).unwrap(), 99);
        p.finish();
        assert_eq!(p.sram_writes(), 4);
        assert_eq!(p.sram_reads(), 2);
        assert_eq!(p.mults_done, 2);
    }

    #[test]
    fn dot_product_accumulates() {
        let mut p = pe(MultFamily::Exact);
        p.load_weights(&[1, 2, 3, 4]).unwrap();
        // 10*1 + 20*2 + 30*3 + 40*4 = 300
        assert_eq!(p.dot(&[10, 20, 30, 40]).unwrap(), 300);
    }

    #[test]
    fn approx_pe_is_close_but_not_exact() {
        let mut p = pe(MultFamily::LogOur);
        p.load_weights(&[100, 200]).unwrap();
        let r = p.compute(0, 123).unwrap() as i64;
        let exact = 12300i64;
        assert!(r != 0);
        assert!(
            ((r - exact).abs() as f64) / (exact as f64) < 0.25,
            "{r} vs {exact}"
        );
    }

    #[test]
    #[should_panic(expected = "COMPUTE")]
    fn compute_before_load_is_a_protocol_error() {
        let mut p = pe(MultFamily::Exact);
        let _ = p.compute(0, 1);
    }

    #[test]
    fn workload_pair_generation() {
        let pairs = ProcessingElement::workload_pairs(&[1, 2], &[10, 20]);
        assert_eq!(pairs, vec![(10, 1), (10, 2), (20, 1), (20, 2)]);
    }
}
