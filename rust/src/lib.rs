//! # OpenACM — an open-source SRAM-based approximate CiM compiler (reproduction)
//!
//! This crate is the Layer-3 (Rust) half of a three-layer reproduction of
//! *"OpenACM: An Open-Source SRAM-Based Approximate CiM Compiler"* (CS.AR 2026):
//!
//! * **L3 (this crate)** — the compiler itself: gate-level netlist generators
//!   for an accuracy-configurable multiplier library (exact 4-2 compressor
//!   tree, tunable approximate 4-2, logarithmic with dynamic compensation),
//!   an event-driven gate simulator, a FreePDK45-calibrated PPA engine, a
//!   transistor-level 6T SRAM macro compiler with variation-aware (MC / MNIS
//!   importance-sampling) characterization, a PE compiler, an OpenROAD
//!   flow-script generator, a DSE engine — plus a threaded serving
//!   coordinator that executes AOT-compiled JAX graphs via PJRT.
//! * **L2 (python/compile/model.py)** — a quantized CNN whose multiplies go
//!   through an approximate-multiplier LUT; lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas LUT-matmul kernel.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index.

pub mod util;
pub mod bench;
pub mod gates;
pub mod mult;
pub mod sim;
pub mod ppa;
pub mod sram;
pub mod yield_analysis;
pub mod pe;
pub mod flow;
pub mod dse;
pub mod apps;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod config;
