//! # OpenACM — an open-source SRAM-based approximate CiM compiler (reproduction)
//!
//! This crate is the Layer-3 (Rust) half of a three-layer reproduction of
//! *"OpenACM: An Open-Source SRAM-Based Approximate CiM Compiler"* (CS.AR 2026):
//!
//! * **L3 (this crate)** — the compiler itself: gate-level netlist generators
//!   for an accuracy-configurable multiplier library (exact 4-2 compressor
//!   tree, tunable approximate 4-2, logarithmic with dynamic compensation),
//!   an event-driven gate simulator, a FreePDK45-calibrated PPA engine, a
//!   transistor-level 6T SRAM macro compiler with variation-aware (MC / MNIS
//!   importance-sampling) characterization, a PE compiler, an OpenROAD
//!   flow-script generator, a DSE engine — plus a sharded, SLO-aware
//!   serving coordinator that executes AOT-compiled JAX graphs via PJRT.
//! * **L2 (python/compile/model.py)** — a quantized CNN whose multiplies go
//!   through an approximate-multiplier LUT; lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas LUT-matmul kernel.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index.
//!
//! ## Simulation engines
//!
//! Gate-level simulation is the compiler's hot loop: every DSE point needs
//! exhaustive error metrics (Tab. IV) and toggle-based activity for power
//! (Tab. II). Two engines implement the common [`sim::Simulator`] trait and
//! are proven bit-identical — outputs *and* per-net toggle counts — by an
//! exhaustive 8-bit sweep over every paper family
//! (`rust/tests/sim_equivalence.rs`):
//!
//! * [`sim::EventSim`] — the scalar event-driven reference. Re-evaluates
//!   only the changed cone, so prefer it for *narrow-cone* streams (the
//!   weight-stationary PE, where few input bits move per vector) and for
//!   debugging, since it processes one vector at a time.
//! * [`sim::BitParallelSim`] — the throughput engine. Every net carries a
//!   `u64` **bit-plane**: lane `l` holds the net's value under input vector
//!   `t + l`, so one topological sweep evaluates 64 vectors with pure
//!   bitwise ops, and toggle counts fall out of `popcount(x ^ (x >> 1))`
//!   plus a one-lane boundary stitch between words. Prefer it whenever
//!   vectors are independent and plentiful: exhaustive characterization,
//!   activity extraction, Monte-Carlo corruption sampling
//!   ([`yield_analysis::functional`], which packs 64 MC samples into the
//!   lanes instead of 64 time steps).
//!
//! Batch work on top of the engines is spread across cores with
//! [`util::threadpool`]: [`mult::error_metrics::exhaustive_netlist`]
//! partitions the operand space, [`sim::activity_parallel`] splits vector
//! streams with a one-vector overlap, and the DSE sweep runs one design
//! point per worker — all deterministic for any thread count.
//! `cargo bench --bench hotpaths` measures the resulting speedup
//! (scalar vs bit-parallel exhaustive INT8 characterization).
//!
//! ## Design-point store
//!
//! Every characterization result is a pure function of a netlist and its
//! parameters, so the [`store`] subsystem makes them persistent and
//! content-addressed:
//!
//! * **Key derivation** — [`store::KeyBuilder`] hashes the netlist's
//!   canonical structural encoding ([`gates::Netlist::canonical_bytes`]:
//!   gate kinds + connectivity + ports, *excluding* instance/debug names)
//!   together with the characterization parameters and a per-domain tag
//!   (`"error-exhaustive/1"`, `"ppa/1"`, `"fyield/1"`, …) into a stable
//!   128-bit [`store::Key128`] (MurmurHash3 x64-128).
//! * **On-disk layout** — `<root>/<hh>/<32-hex-key>.dpr`, 256-way
//!   directory fan-out by the key's top byte; the in-memory index is
//!   sharded across `RwLock`s by the same prefix. Records are written to a
//!   temp file and atomically renamed; every file carries a magic, format
//!   version, length and checksum footer, so torn or bit-rotted records
//!   are detected, deleted and recomputed — never trusted.
//! * **Invalidation** — bumping [`store::FORMAT_VERSION`] invalidates
//!   every record; bumping one domain tag invalidates one record kind;
//!   structural or parameter changes change the key itself. A size-bounded
//!   oldest-first GC (`openacm store gc`) reclaims stale files.
//!
//! Consumers: [`dse::sweep_configs_cached`] serves repeated sweeps from
//! disk (bit-identical to recompute), [`ppa::analyze_macro_cached`] and
//! [`yield_analysis::run_functional_mc_cached`] flow through the same
//! record types, and the serving coordinator warm-starts its per-variant
//! accuracy/energy tables from the store at boot
//! ([`coordinator::warm_start_profiles`]). `cargo bench --bench
//! store_warm` prints the warm-vs-cold sweep speedup and writes
//! `BENCH_store_warm.json`.
//!
//! ## Serving backends
//!
//! The coordinator's batcher workers execute through the
//! [`runtime::Backend`] trait: [`runtime::PjrtBackend`] runs the
//! AOT-compiled JAX graph (needs `make artifacts`), while
//! [`runtime::NativeBackend`] runs the batched Rust-native quantized CNN
//! — [`nn::quant::lut_matmul_batched`], a tile-blocked int8 LUT-GEMM with
//! i32→i64 accumulation that is *bit-identical* to the naive reference —
//! so the whole serving stack works with zero artifacts
//! (`openacm serve --backend native`). See `runtime::backend` for the
//! dispatch rules and batching invariants, and `cargo bench --bench
//! nn_forward` for the scalar-vs-batched speedup trail
//! (`BENCH_nn_forward.json`).
//!
//! ## Sharded, SLO-aware serving
//!
//! [`coordinator`] is a sharded serving layer: requests spread across N
//! coordinator shards by consistent hashing of the payload
//! ([`coordinator::HashRing`]); within a shard each variant runs
//! admission → deadline-bucket batching → execute → respond as decoupled
//! stages over **bounded** channels, so overload becomes backpressure and
//! typed sheds rather than unbounded queues. Requests route by explicit
//! variant or by [`coordinator::AccuracyClass`] — the
//! [`coordinator::RoutingTable`] picks the cheapest variant whose
//! store-measured calibration accuracy satisfies the class, falling back
//! to exact. Worker panics fail fast (never hang), poison only their
//! worker, and turn the `openacm serve` exit non-zero via
//! [`coordinator::Health`]. The invariants — exact accounting
//! (`delivered + shed + rejected == submitted`), bit-identical
//! deliveries, cheapest-satisfying routing — are property-tested across
//! shard counts and adversarial arrival patterns
//! ([`util::proptest::adversarial_workload`]) in
//! `rust/tests/serving_shard.rs`, soaked at million-request scale
//! (`--ignored`), and benchmarked by `cargo bench --bench serving`
//! (`BENCH_serving.json`). See DESIGN.md §"Sharded serving".
//!
//! On top sits a fault-tolerance + elasticity layer
//! ([`coordinator::ResilienceConfig`], everything off by default):
//! retry-with-backoff for transient execute failures (`--retries`),
//! deadline-slack hedging onto a second shard with claim-based
//! exactly-once delivery (`--hedge`), per-variant circuit breakers and a
//! class-routing degradation ladder (`--breaker`), panicked-executor
//! respawn under a rate-limited restart budget (`--respawn`; exhaustion
//! still exits non-zero), and queue-pressure worker autoscaling
//! (`--autoscale`). Proven under seeded fault plans
//! ([`runtime::FaultPlan`], `openacm serve --chaos SEED`) by the chaos
//! property suite in `rust/tests/chaos.rs`. See DESIGN.md §"Fault
//! tolerance & elasticity".
//!
//! ## The compile pass
//!
//! [`compile`] closes the loop from "accuracy budget in" to "deployable
//! heterogeneous design out": `openacm compile --spec … --budget 0.5`
//! profiles per-layer sensitivity (one layer's LUT swapped at a time
//! through [`nn::model::QuantCnn::forward_batch_hetero`]), runs a greedy
//! energy descent with pairwise-swap refinement over the joint per-layer
//! assignment — every accepted step validated by its *measured* top-1 on
//! the calibration set — and emits a versioned [`compile::CompiledPlan`]
//! artifact (layer → multiplier config + energy estimate). Plans execute
//! natively ([`runtime::NativeFactory::add_plan`] registers a plan as a
//! serving variant; logits bit-match a direct heterogeneous forward) and
//! every accuracy measurement is store-memoized on
//! `model hash × assignment × calibration hash`, so repeated compiles and
//! budget sweeps are warm (`cargo bench --bench compile`,
//! `BENCH_compile.json`). Fresh measurements are **incremental**: the
//! batched forward is split into resumable per-layer stages
//! ([`nn::model::BatchCheckpoint`]), so a probe replays only the suffix
//! from its first changed layer — and past the last non-exact layer, a
//! sparse linear delta against the pinned all-exact
//! [`nn::model::ReferenceChain`] — bit-identically to a full forward at
//! a fraction of the GEMM MACs (DESIGN.md §Compile pass, "Incremental
//! evaluation"; `--no-incremental` keeps the full path for A/B
//! debugging).
//!
//! ## Observability
//!
//! Every subsystem reports through one telemetry spine, [`obs`]: a
//! process-wide metrics registry (named counters/gauges + fixed-memory
//! log-bucketed latency histograms on sharded atomics), RAII span tracing
//! (`obs::span`, `OPENACM_TRACE` switch) and a structured JSONL event log
//! that absorbs the old bare `eprintln!` warnings. The coordinator's
//! request lifecycle, the compile search's probe/MAC accounting, the
//! design-point store's hit/miss counters, SIMD dispatch and the
//! threadpool all land in the same registry; `openacm serve
//! --metrics-every N` flushes merged snapshots that `openacm obs
//! snapshot|tail|diff` reads back. See DESIGN.md §Observability for the
//! architecture, naming conventions and the ≤2% overhead budget
//! (`benches/nn_forward.rs` enforces it).
//!
//! On top of the spine sits an analysis layer. [`obs::trace`] threads a
//! zero-allocation trace context through every admitted request
//! (admission → batch → execute → respond stage timestamps) and
//! tail-samples at completion: every shed/failed/deadline-missed request
//! keeps its full timeline, plus the top-K slowest and a 1-in-N healthy
//! baseline, exported as Chrome trace-event JSON and linked into the
//! latency histograms as per-bucket exemplar ids (`openacm obs trace`).
//! [`obs::slo`] runs a Google-SRE-style multi-window burn-rate engine
//! over availability/latency/routing objectives, publishing
//! `serve.slo.*` gauges and `[slo]` summary lines (`openacm obs health`
//! exits 2 while any objective burns at error speed). [`obs::regress`]
//! gates the benches' `BENCH_*.json` ratios against committed floors in
//! `benches/baseline/` (`openacm obs regress`, exit 1 on regression —
//! CI runs it after the smoke benches).

pub mod util;
pub mod obs;
pub mod bench;
pub mod store;
pub mod gates;
pub mod mult;
pub mod sim;
pub mod ppa;
pub mod sram;
pub mod yield_analysis;
pub mod pe;
pub mod flow;
pub mod dse;
pub mod apps;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod compile;
