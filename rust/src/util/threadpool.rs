//! A small fixed-size thread pool with a work-stealing-free, channel-based
//! design (the offline environment has no tokio/rayon). Two entry points:
//!
//! * [`ThreadPool::execute`] — fire-and-forget jobs.
//! * [`parallel_map`] — the main primitive used by the compiler: evenly
//!   chunked, deterministic, panics propagate.
//!
//! Determinism note: `parallel_map` assigns chunk `i` to a worker but writes
//! results back by index, so output order never depends on scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => {
                            // Busy-time clocks only when tracing is on so
                            // the disabled path stays two branch-free loads.
                            if crate::obs::trace_enabled() {
                                let t0 = std::time::Instant::now();
                                job();
                                crate::obs::record_pool_busy_us(
                                    t0.elapsed().as_micros() as u64
                                );
                            } else {
                                job();
                            }
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self {
            workers,
            tx: Some(tx),
        }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        crate::obs::record_pool_tasks(1);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker threads gone");
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to `0..n` across `threads` scoped workers and collect results
/// in index order. Panics in workers propagate to the caller.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    crate::obs::record_pool_tasks(n as u64);
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
    let traced = crate::obs::trace_enabled();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut busy_us = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if traced {
                        let t0 = std::time::Instant::now();
                        let v = f(i);
                        busy_us += t0.elapsed().as_micros() as u64;
                        **slots[i].lock().unwrap() = Some(v);
                    } else {
                        let v = f(i);
                        **slots[i].lock().unwrap() = Some(v);
                    }
                }
                if busy_us > 0 {
                    crate::obs::record_pool_busy_us(busy_us);
                }
            });
        }
    });
    drop(slots);
    out.into_iter().map(|v| v.expect("slot not filled")).collect()
}

/// Parallel fold: run `chunks` independent accumulations of `f` (given the
/// chunk index) then reduce with `merge`. Deterministic reduction order.
pub fn parallel_fold<A, F, M>(chunks: usize, threads: usize, f: F, merge: M) -> A
where
    A: Send,
    F: Fn(usize) -> A + Sync,
    M: Fn(A, A) -> A,
{
    let parts = parallel_map(chunks, threads, f);
    let mut it = parts.into_iter();
    let first = it.next().expect("parallel_fold needs >= 1 chunk");
    it.fold(first, merge)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for completion.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_order_and_completeness() {
        let v = parallel_map(1000, 8, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_map_single_thread_path() {
        let v = parallel_map(5, 1, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_fold_sums() {
        let total = parallel_fold(16, 4, |i| (i as u64) * 10, |a, b| a + b);
        assert_eq!(total, (0..16u64).map(|i| i * 10).sum());
    }

    #[test]
    fn parallel_map_empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }
}
