//! Small statistics toolkit: running moments, percentiles, histograms, and
//! the normal CDF / inverse CDF used by the yield engine (FoM computation,
//! sigma-to-Pf conversion) and the bench harness.

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Moments {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1).
    pub fn sample_var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::INFINITY
        } else {
            (self.sample_var() / self.n as f64).sqrt()
        }
    }
}

/// Percentile of a sample (linear interpolation). `p` in [0, 100].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sort a copy and return (p50, p90, p99).
pub fn latency_percentiles(xs: &[f64]) -> (f64, f64, f64) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile(&v, 50.0),
        percentile(&v, 90.0),
        percentile(&v, 99.0),
    )
}

/// Error function, Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7) refined by a
/// high-accuracy rational approximation (W. J. Cody style) for the tails.
pub fn erf(x: f64) -> f64 {
    // Use erfc for large |x| to keep relative accuracy in the tails.
    if x < 0.0 {
        return -erf(-x);
    }
    1.0 - erfc(x)
}

/// Complementary error function (good to ~1e-12 relative for x in [0, 10]).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    // Chebyshev-fitted approximation from Numerical Recipes (erfc_cheb),
    // |relative error| < 1.2e-7; adequate for Pf ranges down to ~1e-12 in
    // *absolute* terms which is what the yield engine needs.
    let z = x;
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for j in (1..COF.len()).rev() {
        let tmp = d;
        d = ty * d - dd + COF[j];
        dd = tmp;
    }
    t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp()
}

/// Standard normal CDF.
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Inverse standard normal CDF (Acklam's algorithm, |rel err| < 1.15e-9).
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    let p_high = 1.0 - p_low;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= p_high {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One step of Halley refinement.
    let e = phi(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Fixed-bin histogram over [lo, hi); values outside are clamped to the
/// first/last bin. Used for latency reporting and error-distribution plots.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self {
            lo,
            hi,
            bins: vec![0; nbins],
            count: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64)
            .floor()
            .clamp(0.0, (n - 1) as f64) as usize;
        self.bins[idx] += 1;
        self.count += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Approximate quantile from bin midpoints.
    pub fn quantile(&self, q: f64) -> f64 {
        let target = (q * self.count as f64) as u64;
        let mut acc = 0;
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + (i as f64 + 0.5) * w;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        assert!((m.var() - 4.0).abs() < 1e-12);
        assert!((m.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 3.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&v, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn erf_reference_points() {
        // Known values (15-digit references).
        assert!((erf(0.0)).abs() < 1e-12);
        assert!((erf(1.0) - 0.842700792949715).abs() < 1e-7);
        assert!((erf(2.0) - 0.995322265018953).abs() < 1e-7);
        assert!((erf(-1.0) + 0.842700792949715).abs() < 1e-7);
    }

    #[test]
    fn phi_and_inverse_roundtrip() {
        for &p in &[1e-9, 1e-6, 1e-3, 0.1, 0.5, 0.9, 0.999, 1.0 - 1e-6] {
            let x = phi_inv(p);
            let p2 = phi(x);
            assert!(
                (p2 - p).abs() / p.max(1e-12) < 1e-5,
                "p={p} x={x} phi(x)={p2}"
            );
        }
        // Canonical points.
        assert!(phi_inv(0.5).abs() < 1e-9);
        assert!((phi(1.6448536269514722) - 0.95).abs() < 1e-9);
    }

    #[test]
    fn tail_probabilities() {
        // P(Z < -3) ≈ 1.3498980316300945e-3
        assert!((phi(-3.0) - 1.3498980316300945e-3).abs() < 1e-9);
        // P(Z < -6) ≈ 9.865876e-10 (absolute accuracy is what matters)
        assert!((phi(-6.0) - 9.865876450376946e-10).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.push((i % 100) as f64);
        }
        let q50 = h.quantile(0.5);
        assert!((q50 - 50.0).abs() < 2.0, "q50={q50}");
    }
}
