//! Tiny CLI argument parser (the offline environment has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Typed getters parse on demand and produce readable errors.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand, options and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (if the caller asked for subcommand mode).
    pub command: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    ///
    /// `boolean_flags` lists options that never take a value; everything else
    /// written as `--key` consumes the next token as its value (or uses the
    /// `=`-joined form).
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        subcommand: bool,
        boolean_flags: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        if subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    args.command = it.next();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if boolean_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    args.opts.entry(body.to_string()).or_default().push(v);
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options are not supported: {tok}");
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse std::env::args() (skipping argv[0]).
    pub fn from_env(subcommand: bool, boolean_flags: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), subcommand, boolean_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.opts
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse(
            toks("ppa --rows 64 --width=32 --verbose extra"),
            true,
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("ppa"));
        assert_eq!(a.usize_or("rows", 0).unwrap(), 64);
        assert_eq!(a.usize_or("width", 0).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(toks("run --out"), true, &[]).unwrap_err();
        assert!(e.to_string().contains("--out"));
    }

    #[test]
    fn repeated_options_collect() {
        let a = Args::parse(toks("--mult exact --mult logour"), false, &[]).unwrap();
        assert_eq!(a.get_all("mult"), vec!["exact", "logour"]);
        assert_eq!(a.get("mult"), Some("logour")); // last wins for scalar get
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(toks(""), false, &[]).unwrap();
        assert_eq!(a.usize_or("n", 5).unwrap(), 5);
        assert!((a.f64_or("x", 1.5).unwrap() - 1.5).abs() < 1e-12);
        assert!(a.required("name").is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = Args::parse(toks("run -- --not-an-option"), true, &[]).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn bad_number_message() {
        let a = Args::parse(toks("--n abc"), false, &[]).unwrap();
        let e = a.usize_or("n", 0).unwrap_err();
        assert!(e.to_string().contains("--n"));
    }
}
