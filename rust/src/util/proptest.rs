//! Miniature property-testing harness (no `proptest` crate offline), plus
//! the deterministic adversarial workload generator the serving test and
//! bench suites share ([`adversarial_workload`]).
//!
//! Usage pattern inside a `#[test]`:
//!
//! ```ignore
//! check(1000, 0xSEED, |g| {
//!     let a = g.u64_bits(8);
//!     let b = g.u64_bits(8);
//!     prop_assert(behavioral(a, b) == netlist(a, b), "mismatch");
//! });
//! ```
//!
//! On failure the harness retries with progressively "smaller" generated
//! values (halving shrink on integers) and reports the minimal failing case
//! it found together with the seed, so failures are reproducible.

use crate::util::rng::Pcg32;

/// Value generator handed to properties. Records drawn integers so the
/// harness can shrink them.
pub struct Gen<'a> {
    rng: &'a mut Pcg32,
    drawn: Vec<u64>,
    replay: Option<Vec<u64>>,
    cursor: usize,
}

impl<'a> Gen<'a> {
    fn new(rng: &'a mut Pcg32, replay: Option<Vec<u64>>) -> Self {
        Self {
            rng,
            drawn: Vec::new(),
            replay,
            cursor: 0,
        }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Pcg32) -> u64) -> u64 {
        let v = match &self.replay {
            Some(vals) if self.cursor < vals.len() => vals[self.cursor],
            _ => fresh(self.rng),
        };
        self.cursor += 1;
        self.drawn.push(v);
        v
    }

    /// Uniform integer with `bits` random low bits.
    pub fn u64_bits(&mut self, bits: u32) -> u64 {
        assert!(bits <= 64);
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        self.draw(|r| r.next_u64() & mask)
    }

    /// Uniform in [0, bound).
    pub fn usize_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        self.draw(|r| r.below(bound as u32) as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.draw(|r| r.next_u64() % (hi - lo + 1)) % (hi - lo + 1)
    }

    /// Uniform f64 in [0,1).
    pub fn f64_unit(&mut self) -> f64 {
        self.draw(|r| r.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick one element of a slice.
    pub fn choose<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.usize_below(xs.len())]
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`; on failure shrink by repeatedly
/// halving each drawn integer, and panic with the minimal counterexample.
pub fn check<F>(cases: usize, seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let mut g = Gen::new(&mut rng, None);
        if let Err(msg) = prop(&mut g) {
            let failing = g.drawn.clone();
            let (min_vals, min_msg) = shrink(&prop, failing, msg);
            panic!(
                "property failed (seed={seed}, case={case}):\n  {min_msg}\n  minimal draws: {min_vals:?}"
            );
        }
    }
}

fn shrink<F>(prop: &F, mut vals: Vec<u64>, mut msg: String) -> (Vec<u64>, String)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let run = |vals: &[u64]| -> Option<String> {
        let mut dummy_rng = Pcg32::new(0);
        let mut g = Gen::new(&mut dummy_rng, Some(vals.to_vec()));
        prop(&mut g).err()
    };
    // Per-coordinate minimization: try 0 directly, else binary-search the
    // smallest failing value assuming per-coordinate monotonicity (exact
    // for monotone properties, a good heuristic otherwise). Repeat until
    // a full pass makes no progress.
    let mut improved = true;
    let mut passes = 0;
    while improved && passes < 8 {
        improved = false;
        passes += 1;
        for i in 0..vals.len() {
            if vals[i] == 0 {
                continue;
            }
            let mut trial = vals.clone();
            trial[i] = 0;
            if let Some(m) = run(&trial) {
                vals = trial;
                msg = m;
                improved = true;
                continue;
            }
            // lo passes, hi = vals[i] fails.
            let mut lo = 0u64;
            let mut hi = vals[i];
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                let mut t = vals.clone();
                t[i] = mid;
                match run(&t) {
                    Some(m) => {
                        hi = mid;
                        msg = m;
                    }
                    None => lo = mid,
                }
            }
            if hi != vals[i] {
                vals[i] = hi;
                improved = true;
            }
        }
    }
    (vals, msg)
}

// ---------------------------------------------------------------------------
// Adversarial workload generator (serving tests + benches)
// ---------------------------------------------------------------------------

/// Arrival shapes for the serving harness. All timing is *virtual*
/// (µs offsets baked into the stream at generation time from the seeded
/// RNG — no wall-clock randomness anywhere), so the same seed replays
/// byte-identically; callers may honor the gaps or replay at maximum
/// pressure by ignoring them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Independent exponential inter-arrival gaps (open-loop Poisson).
    Poisson,
    /// Tight back-to-back bursts separated by long idle gaps — stresses
    /// admission shedding and batch-width amortization.
    Burst,
    /// A trickle with gaps far above the batching window — stresses the
    /// deadline-proximity close rule (a size/timeout-only batcher idles
    /// the full window per request).
    SlowLoris,
    /// Poisson arrivals where a slice of payloads are malformed
    /// (wrong-size images) — the server must reject them at the door
    /// without poisoning batchmates.
    MalformedFlood,
}

/// The four adversarial shapes the serving property suite sweeps.
pub const ADVERSARIAL_PATTERNS: [ArrivalPattern; 4] = [
    ArrivalPattern::Poisson,
    ArrivalPattern::Burst,
    ArrivalPattern::SlowLoris,
    ArrivalPattern::MalformedFlood,
];

/// One synthetic request in a generated stream. Pure indices + sizes —
/// the generator knows nothing about images, variants or classes beyond
/// the menu sizes in [`WorkloadSpec`], so tests and benches map them onto
/// whatever pools they own.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SynthRequest {
    /// Virtual arrival time, µs since stream start (non-decreasing).
    pub at_us: u64,
    /// Index into the caller's image pool (`< spec.images`).
    pub image: usize,
    /// Index into the caller's variant menu (`< spec.variants`).
    pub variant: usize,
    /// `Some(i)`: route by the caller's accuracy class `i`
    /// (`< spec.classes`) instead of by `variant`.
    pub class: Option<usize>,
    /// `Some(n)`: send a malformed payload of `n` bytes (never the
    /// well-formed size) instead of image `image`.
    pub malformed: Option<usize>,
}

/// Shape parameters for [`adversarial_workload`].
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    pub pattern: ArrivalPattern,
    /// Requests to generate.
    pub n: usize,
    /// Image-pool size the `image` indices draw from.
    pub images: usize,
    /// Variant-menu size the `variant` indices draw from.
    pub variants: usize,
    /// Accuracy-class menu size; 0 disables class routing, otherwise
    /// roughly half the stream routes by class (the "class mix").
    pub classes: usize,
    /// Mean inter-arrival gap for [`ArrivalPattern::Poisson`]; bursts
    /// idle ~50× this between bursts, slow-loris trickles at ~20×.
    pub mean_gap_us: u64,
    /// Well-formed payload size in bytes; malformed payloads are sized
    /// to never equal it.
    pub payload: usize,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            pattern: ArrivalPattern::Poisson,
            n: 1000,
            images: 64,
            variants: 4,
            classes: 0,
            mean_gap_us: 100,
            payload: 256,
        }
    }
}

/// Generate a deterministic adversarial request stream: same `seed` +
/// `spec` → an identical `Vec` on every call, machine and run (the RNG is
/// [`Pcg32`]; no wall clock, no hasher ambient state).
pub fn adversarial_workload(seed: u64, spec: &WorkloadSpec) -> Vec<SynthRequest> {
    assert!(spec.images > 0 && spec.variants > 0, "empty image/variant menu");
    let mut rng = Pcg32::new(seed ^ 0xADE5_A21A_1000_0000u64.wrapping_add(spec.pattern as u64));
    let mean = spec.mean_gap_us.max(1) as f64;
    let mut at_us = 0u64;
    let mut burst_left = 0usize;
    let mut out = Vec::with_capacity(spec.n);
    for _ in 0..spec.n {
        // Arrival-time advance per pattern.
        let gap = match spec.pattern {
            ArrivalPattern::Poisson | ArrivalPattern::MalformedFlood => exp_gap(&mut rng, mean),
            ArrivalPattern::SlowLoris => exp_gap(&mut rng, mean * 20.0) + spec.mean_gap_us * 10,
            ArrivalPattern::Burst => {
                if burst_left == 0 {
                    burst_left = 8 + rng.below(57) as usize; // bursts of 8..=64
                    exp_gap(&mut rng, mean * 50.0)
                } else {
                    0
                }
            }
        };
        burst_left = burst_left.saturating_sub(1);
        at_us = at_us.saturating_add(gap);
        // 1-in-5 payloads of a malformed flood are malformed.
        let malformed = if spec.pattern == ArrivalPattern::MalformedFlood && rng.below(5) == 0 {
            Some(malformed_size(&mut rng, spec.payload))
        } else {
            None
        };
        // Class mix: about half the stream routes by accuracy class.
        let class = if spec.classes > 0 && rng.below(2) == 0 {
            Some(rng.below(spec.classes as u32) as usize)
        } else {
            None
        };
        out.push(SynthRequest {
            at_us,
            image: rng.below(spec.images as u32) as usize,
            variant: rng.below(spec.variants as u32) as usize,
            class,
            malformed,
        });
    }
    out
}

/// Exponential inter-arrival gap with the given mean, in whole µs.
fn exp_gap(rng: &mut Pcg32, mean_us: f64) -> u64 {
    let u = rng.next_f64().clamp(1e-12, 1.0 - 1e-12);
    (-u.ln() * mean_us).round() as u64
}

/// A payload size that is never the well-formed one: boundary sizes
/// (0, 1, ±1 around `payload`) plus random small/large outliers.
fn malformed_size(rng: &mut Pcg32, payload: usize) -> usize {
    let candidates = [
        0,
        1,
        payload.saturating_sub(1),
        payload + 1,
        payload * 16,
        rng.below(4096) as usize,
    ];
    let pick = candidates[rng.below(candidates.len() as u32) as usize];
    if pick == payload {
        payload + 1
    } else {
        pick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(500, 1, |g| {
            let a = g.u64_bits(16);
            let b = g.u64_bits(16);
            prop_assert(a + b == b + a, "addition commutes")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(500, 2, |g| {
            let a = g.u64_bits(8);
            prop_assert(a < 200, format!("a={a} exceeded"))
        });
    }

    #[test]
    fn shrinker_finds_small_counterexample() {
        // Capture the panic message to confirm the shrinker reduced the case.
        let result = std::panic::catch_unwind(|| {
            check(1000, 3, |g| {
                let a = g.u64_bits(16);
                prop_assert(a < 100, format!("a={a}"))
            });
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // Minimal failing value for `a < 100` is 100 exactly.
        assert!(msg.contains("a=100"), "shrunk message: {msg}");
    }

    #[test]
    fn workload_generator_is_deterministic_and_in_range() {
        for pattern in ADVERSARIAL_PATTERNS {
            let spec = WorkloadSpec {
                pattern,
                n: 2000,
                images: 32,
                variants: 4,
                classes: 3,
                ..WorkloadSpec::default()
            };
            let a = adversarial_workload(0xFEED, &spec);
            let b = adversarial_workload(0xFEED, &spec);
            assert_eq!(a, b, "same seed must replay byte-identically");
            let c = adversarial_workload(0xBEEF, &spec);
            assert_ne!(a, c, "different seed must differ");
            assert_eq!(a.len(), 2000);
            let mut prev = 0u64;
            for r in &a {
                assert!(r.at_us >= prev, "arrival times must be non-decreasing");
                prev = r.at_us;
                assert!(r.image < 32 && r.variant < 4);
                if let Some(cls) = r.class {
                    assert!(cls < 3);
                }
                if let Some(sz) = r.malformed {
                    assert_ne!(sz, spec.payload, "malformed size equals payload");
                }
            }
            // The class mix really mixes.
            let classed = a.iter().filter(|r| r.class.is_some()).count();
            assert!(classed > 500 && classed < 1500, "class mix {classed}/2000");
        }
    }

    #[test]
    fn workload_patterns_have_their_shapes() {
        let spec = |pattern| WorkloadSpec {
            pattern,
            n: 2000,
            ..WorkloadSpec::default()
        };
        // Burst: plenty of zero-gap adjacencies.
        let burst = adversarial_workload(7, &spec(ArrivalPattern::Burst));
        let zero_gaps = burst
            .windows(2)
            .filter(|w| w[1].at_us == w[0].at_us)
            .count();
        assert!(zero_gaps > 1000, "bursts must arrive back-to-back ({zero_gaps})");
        // Slow loris: every gap dwarfs the Poisson mean.
        let loris = adversarial_workload(7, &spec(ArrivalPattern::SlowLoris));
        let min_gap = loris
            .windows(2)
            .map(|w| w[1].at_us - w[0].at_us)
            .min()
            .unwrap();
        assert!(min_gap >= 1000, "slow-loris trickle gap {min_gap}µs too small");
        // Poisson: no malformed payloads; flood: a meaningful slice, but
        // well-formed requests survive alongside them.
        assert!(adversarial_workload(7, &spec(ArrivalPattern::Poisson))
            .iter()
            .all(|r| r.malformed.is_none()));
        let flood = adversarial_workload(7, &spec(ArrivalPattern::MalformedFlood));
        let bad = flood.iter().filter(|r| r.malformed.is_some()).count();
        assert!(bad > 200 && bad < 800, "flood malformed share {bad}/2000");
    }

    #[test]
    fn choose_picks_valid_elements() {
        check(200, 4, |g| {
            let xs = [1, 2, 3];
            let x = *g.choose(&xs);
            prop_assert(xs.contains(&x), "chosen element must be in slice")
        });
    }
}
