//! Miniature property-testing harness (no `proptest` crate offline).
//!
//! Usage pattern inside a `#[test]`:
//!
//! ```ignore
//! check(1000, 0xSEED, |g| {
//!     let a = g.u64_bits(8);
//!     let b = g.u64_bits(8);
//!     prop_assert(behavioral(a, b) == netlist(a, b), "mismatch");
//! });
//! ```
//!
//! On failure the harness retries with progressively "smaller" generated
//! values (halving shrink on integers) and reports the minimal failing case
//! it found together with the seed, so failures are reproducible.

use crate::util::rng::Pcg32;

/// Value generator handed to properties. Records drawn integers so the
/// harness can shrink them.
pub struct Gen<'a> {
    rng: &'a mut Pcg32,
    drawn: Vec<u64>,
    replay: Option<Vec<u64>>,
    cursor: usize,
}

impl<'a> Gen<'a> {
    fn new(rng: &'a mut Pcg32, replay: Option<Vec<u64>>) -> Self {
        Self {
            rng,
            drawn: Vec::new(),
            replay,
            cursor: 0,
        }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Pcg32) -> u64) -> u64 {
        let v = match &self.replay {
            Some(vals) if self.cursor < vals.len() => vals[self.cursor],
            _ => fresh(self.rng),
        };
        self.cursor += 1;
        self.drawn.push(v);
        v
    }

    /// Uniform integer with `bits` random low bits.
    pub fn u64_bits(&mut self, bits: u32) -> u64 {
        assert!(bits <= 64);
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        self.draw(|r| r.next_u64() & mask)
    }

    /// Uniform in [0, bound).
    pub fn usize_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        self.draw(|r| r.below(bound as u32) as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.draw(|r| r.next_u64() % (hi - lo + 1)) % (hi - lo + 1)
    }

    /// Uniform f64 in [0,1).
    pub fn f64_unit(&mut self) -> f64 {
        self.draw(|r| r.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick one element of a slice.
    pub fn choose<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.usize_below(xs.len())]
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`; on failure shrink by repeatedly
/// halving each drawn integer, and panic with the minimal counterexample.
pub fn check<F>(cases: usize, seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let mut g = Gen::new(&mut rng, None);
        if let Err(msg) = prop(&mut g) {
            let failing = g.drawn.clone();
            let (min_vals, min_msg) = shrink(&prop, failing, msg);
            panic!(
                "property failed (seed={seed}, case={case}):\n  {min_msg}\n  minimal draws: {min_vals:?}"
            );
        }
    }
}

fn shrink<F>(prop: &F, mut vals: Vec<u64>, mut msg: String) -> (Vec<u64>, String)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let run = |vals: &[u64]| -> Option<String> {
        let mut dummy_rng = Pcg32::new(0);
        let mut g = Gen::new(&mut dummy_rng, Some(vals.to_vec()));
        prop(&mut g).err()
    };
    // Per-coordinate minimization: try 0 directly, else binary-search the
    // smallest failing value assuming per-coordinate monotonicity (exact
    // for monotone properties, a good heuristic otherwise). Repeat until
    // a full pass makes no progress.
    let mut improved = true;
    let mut passes = 0;
    while improved && passes < 8 {
        improved = false;
        passes += 1;
        for i in 0..vals.len() {
            if vals[i] == 0 {
                continue;
            }
            let mut trial = vals.clone();
            trial[i] = 0;
            if let Some(m) = run(&trial) {
                vals = trial;
                msg = m;
                improved = true;
                continue;
            }
            // lo passes, hi = vals[i] fails.
            let mut lo = 0u64;
            let mut hi = vals[i];
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                let mut t = vals.clone();
                t[i] = mid;
                match run(&t) {
                    Some(m) => {
                        hi = mid;
                        msg = m;
                    }
                    None => lo = mid,
                }
            }
            if hi != vals[i] {
                vals[i] = hi;
                improved = true;
            }
        }
    }
    (vals, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(500, 1, |g| {
            let a = g.u64_bits(16);
            let b = g.u64_bits(16);
            prop_assert(a + b == b + a, "addition commutes")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(500, 2, |g| {
            let a = g.u64_bits(8);
            prop_assert(a < 200, format!("a={a} exceeded"))
        });
    }

    #[test]
    fn shrinker_finds_small_counterexample() {
        // Capture the panic message to confirm the shrinker reduced the case.
        let result = std::panic::catch_unwind(|| {
            check(1000, 3, |g| {
                let a = g.u64_bits(16);
                prop_assert(a < 100, format!("a={a}"))
            });
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // Minimal failing value for `a < 100` is 100 exactly.
        assert!(msg.contains("a=100"), "shrunk message: {msg}");
    }

    #[test]
    fn choose_picks_valid_elements() {
        check(200, 4, |g| {
            let xs = [1, 2, 3];
            let x = *g.choose(&xs);
            prop_assert(xs.contains(&x), "chosen element must be in slice")
        });
    }
}
