//! Shared substrates: deterministic RNG, statistics, `.npy` interchange,
//! CLI parsing, a scoped thread pool, runtime SIMD dispatch, and a
//! miniature property-testing harness. All hand-built (the build
//! environment is offline; see `Cargo.toml`), and each is exercised by its
//! own unit tests.

pub mod rng;
pub mod stats;
pub mod npy;
pub mod cli;
pub mod threadpool;
pub mod simd;
pub mod proptest;
