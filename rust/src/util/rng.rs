//! Deterministic, seedable random number generation.
//!
//! Two generators are provided:
//!
//! * [`SplitMix64`] — used for seeding and cheap hashing-style streams.
//! * [`Pcg32`] — the main generator (PCG-XSH-RR 64/32), statistically solid,
//!   16 bytes of state, trivially forkable into independent streams (used by
//!   the Monte-Carlo yield engine so every worker thread owns its own
//!   deterministic stream).
//!
//! Gaussian sampling uses Box-Muller with a cached spare.

/// SplitMix64 (Steele et al.) — seeds other generators, never used for MC.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 with Box-Muller gaussian support.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Create from a seed; the stream id is derived from the seed so two
    /// different seeds give fully independent sequences.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::with_stream(sm.next_u64(), sm.next_u64())
    }

    /// Explicit (state, stream) construction.
    pub fn with_stream(initstate: u64, initseq: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
            gauss_spare: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(initstate);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Fork an independent child stream (deterministic from parent state).
    pub fn fork(&mut self, idx: u64) -> Pcg32 {
        let s = self.next_u64() ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire-style).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi].
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller (cached spare).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_gaussian()
    }

    /// Fill a slice with standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg_deterministic_and_distinct_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let mut c = Pcg32::new(43);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fork_gives_independent_streams() {
        let mut root = Pcg32::new(7);
        let mut k1 = root.fork(1);
        let mut k2 = root.fork(2);
        let s1: Vec<u32> = (0..4).map(|_| k1.next_u32()).collect();
        let s2: Vec<u32> = (0..4).map(|_| k2.next_u32()).collect();
        assert_ne!(s1, s2);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Pcg32::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.below(7);
            assert!(y < 7);
            let z = r.range_u32(3, 5);
            assert!((3..=5).contains(&z));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg32::new(99);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // expectation 10_000, allow 5% deviation
            assert!((9_500..=10_500).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(5);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = r.next_gaussian();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
