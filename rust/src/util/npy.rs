//! Minimal NumPy `.npy` (format version 1.0) reader/writer.
//!
//! This is the interchange format between the Python build path (which emits
//! approximate-multiplier LUTs, quantized CNN weights, and evaluation
//! datasets) and the Rust runtime. Only what we need is implemented:
//! little-endian `i32`, `f32`, `u8`, and `i64` arrays, C-contiguous, any
//! rank. The header is parsed with a small hand-rolled scanner (no serde in
//! the offline environment).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Element type of an array (the subset we use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    I32,
    F32,
    U8,
    I64,
}

impl DType {
    pub fn descr(self) -> &'static str {
        match self {
            DType::I32 => "<i4",
            DType::F32 => "<f4",
            DType::U8 => "|u1",
            DType::I64 => "<i8",
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::I32 | DType::F32 => 4,
            DType::U8 => 1,
            DType::I64 => 8,
        }
    }

    fn from_descr(s: &str) -> Result<Self> {
        Ok(match s {
            "<i4" => DType::I32,
            "<f4" => DType::F32,
            "|u1" | "<u1" => DType::U8,
            "<i8" => DType::I64,
            other => bail!("unsupported npy dtype descr {other:?}"),
        })
    }
}

/// An n-dimensional array as raw bytes + shape + dtype.
#[derive(Clone, Debug)]
pub struct NpyArray {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("expected i32 array, found {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("expected f32 array, found {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u8(&self) -> Result<Vec<u8>> {
        if self.dtype != DType::U8 {
            bail!("expected u8 array, found {:?}", self.dtype);
        }
        Ok(self.data.clone())
    }

    pub fn as_i64(&self) -> Result<Vec<i64>> {
        if self.dtype != DType::I64 {
            bail!("expected i64 array, found {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_f32(shape: &[usize], values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_u8(shape: &[usize], values: &[u8]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Self {
            dtype: DType::U8,
            shape: shape.to_vec(),
            data: values.to_vec(),
        }
    }
}

/// Parse the python-dict header, e.g.
/// `{'descr': '<i4', 'fortran_order': False, 'shape': (256, 256), }`.
fn parse_header(h: &str) -> Result<(DType, bool, Vec<usize>)> {
    let grab = |key: &str| -> Result<String> {
        let kq = format!("'{key}'");
        let at = h.find(&kq).with_context(|| format!("npy header missing {key}"))?;
        let rest = &h[at + kq.len()..];
        let colon = rest.find(':').context("npy header: missing colon")?;
        Ok(rest[colon + 1..].trim_start().to_string())
    };
    let descr_raw = grab("descr")?;
    let descr = descr_raw
        .trim_start_matches(['\'', '"'])
        .chars()
        .take_while(|c| *c != '\'' && *c != '"')
        .collect::<String>();
    let fortran = grab("fortran_order")?.starts_with("True");
    let shape_raw = grab("shape")?;
    if !shape_raw.starts_with('(') {
        bail!("npy header: bad shape field {shape_raw:?}");
    }
    let inner: String = shape_raw[1..]
        .chars()
        .take_while(|c| *c != ')')
        .collect();
    let shape: Vec<usize> = inner
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("npy header: bad dim"))
        .collect::<Result<_>>()?;
    Ok((DType::from_descr(&descr)?, fortran, shape))
}

/// Read a `.npy` file.
pub fn read(path: &Path) -> Result<NpyArray> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading npy {}", path.display()))?;
    read_bytes(&bytes).with_context(|| format!("parsing npy {}", path.display()))
}

/// Read from an in-memory buffer.
pub fn read_bytes(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not a npy file (bad magic)");
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        ),
        v => bail!("unsupported npy version {v}"),
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
        .context("npy header not utf8")?;
    let (dtype, fortran, shape) = parse_header(header)?;
    if fortran {
        bail!("fortran-order npy arrays are not supported");
    }
    let n: usize = shape.iter().product();
    let data_start = header_start + header_len;
    let need = n * dtype.size();
    if bytes.len() < data_start + need {
        bail!(
            "npy payload truncated: need {need} bytes, have {}",
            bytes.len() - data_start
        );
    }
    Ok(NpyArray {
        dtype,
        shape,
        data: bytes[data_start..data_start + need].to_vec(),
    })
}

/// Write a `.npy` file (version 1.0, 64-byte-aligned header).
pub fn write(path: &Path, arr: &NpyArray) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating npy {}", path.display()))?;
    write_to(&mut f, arr)
}

pub fn write_to<W: Write>(w: &mut W, arr: &NpyArray) -> Result<()> {
    let shape_str = match arr.shape.len() {
        1 => format!("({},)", arr.shape[0]),
        _ => format!(
            "({})",
            arr.shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        arr.dtype.descr(),
        shape_str
    );
    // Pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64, ending in \n.
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    w.write_all(b"\x93NUMPY\x01\x00")?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    w.write_all(&arr.data)?;
    Ok(())
}

/// Convenience: read and keep only the flat i32 payload.
pub fn read_i32(path: &Path) -> Result<(Vec<usize>, Vec<i32>)> {
    let a = read(path)?;
    let v = a.as_i32()?;
    Ok((a.shape, v))
}

/// Convenience: read and keep only the flat f32 payload.
pub fn read_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let a = read(path)?;
    let v = a.as_f32()?;
    Ok((a.shape, v))
}

/// Read a whole directory of `.npy` files into (stem, array) pairs.
pub fn read_dir(dir: &Path) -> Result<Vec<(String, NpyArray)>> {
    let mut out = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "npy").unwrap_or(false))
        .collect();
    entries.sort();
    for p in entries {
        let stem = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("")
            .to_string();
        out.push((stem, read(&p)?));
    }
    Ok(out)
}

/// Stream-read helper used by tests.
pub fn read_from<R: Read>(r: &mut R) -> Result<NpyArray> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    read_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_i32() {
        let arr = NpyArray::from_i32(&[2, 3], &[1, -2, 3, -4, 5, -6]);
        let mut buf = Vec::new();
        write_to(&mut buf, &arr).unwrap();
        let back = read_bytes(&buf).unwrap();
        assert_eq!(back.dtype, DType::I32);
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.as_i32().unwrap(), vec![1, -2, 3, -4, 5, -6]);
    }

    #[test]
    fn roundtrip_f32_1d() {
        let arr = NpyArray::from_f32(&[4], &[0.5, -1.25, 3.75, 0.0]);
        let mut buf = Vec::new();
        write_to(&mut buf, &arr).unwrap();
        let back = read_bytes(&buf).unwrap();
        assert_eq!(back.shape, vec![4]);
        assert_eq!(back.as_f32().unwrap(), vec![0.5, -1.25, 3.75, 0.0]);
    }

    #[test]
    fn roundtrip_u8() {
        let data: Vec<u8> = (0..=255).collect();
        let arr = NpyArray::from_u8(&[16, 16], &data);
        let mut buf = Vec::new();
        write_to(&mut buf, &arr).unwrap();
        let back = read_bytes(&buf).unwrap();
        assert_eq!(back.as_u8().unwrap(), data);
    }

    #[test]
    fn header_is_64_aligned() {
        let arr = NpyArray::from_i32(&[1], &[7]);
        let mut buf = Vec::new();
        write_to(&mut buf, &arr).unwrap();
        // data must start at a multiple of 64
        assert_eq!((buf.len() - 4) % 64, 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_bytes(b"not a npy").is_err());
    }

    #[test]
    fn parses_numpy_style_header_with_spaces() {
        // Hand-built v1 header mimicking numpy's own output formatting.
        let header = "{'descr': '<i4', 'fortran_order': False, 'shape': (3,), }";
        let mut padded = header.to_string();
        let unpadded = 10 + padded.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        padded.push_str(&" ".repeat(pad));
        padded.push('\n');
        let mut buf = Vec::new();
        buf.extend_from_slice(b"\x93NUMPY\x01\x00");
        buf.extend_from_slice(&(padded.len() as u16).to_le_bytes());
        buf.extend_from_slice(padded.as_bytes());
        for v in [10i32, 20, 30] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let arr = read_bytes(&buf).unwrap();
        assert_eq!(arr.as_i32().unwrap(), vec![10, 20, 30]);
    }

    #[test]
    fn scalar_dim_zero_rank_rejected_gracefully() {
        // shape () => product = 1 (empty iterator product); we accept it as len-1.
        let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (), }";
        let mut padded = header.to_string();
        let unpadded = 10 + padded.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        padded.push_str(&" ".repeat(pad));
        padded.push('\n');
        let mut buf = Vec::new();
        buf.extend_from_slice(b"\x93NUMPY\x01\x00");
        buf.extend_from_slice(&(padded.len() as u16).to_le_bytes());
        buf.extend_from_slice(padded.as_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        let arr = read_bytes(&buf).unwrap();
        assert_eq!(arr.as_f32().unwrap(), vec![1.5]);
    }
}
