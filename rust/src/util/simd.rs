//! Runtime SIMD dispatch for the two hot kernels (see DESIGN.md §"SIMD
//! kernels").
//!
//! The crate's raw-speed paths — the blocked LUT-GEMM
//! ([`crate::nn::quant::lut_matmul_batched`]) and the wide bit-plane gate
//! evaluator ([`crate::gates::Netlist::eval_wide_into`] /
//! [`crate::sim::BitParallelSim`]) — pick an instruction tier *at
//! runtime*: AVX2 on x86_64 hosts that report it, NEON on aarch64, and a
//! portable scalar body everywhere else. Three invariants keep this safe
//! and testable:
//!
//! 1. **The scalar body is always compiled and always reachable** — it is
//!    the bit-exactness oracle every vector path is checked against
//!    (`rust/tests/nn_batch_equivalence.rs`, `rust/tests/sim_equivalence.rs`).
//! 2. **Vector paths are bit-identical to the scalar body by
//!    construction**: the GEMM accumulates exact integers (any order gives
//!    the same sum) and the simulator is pure bitwise logic, so dispatch
//!    never changes a single output bit, toggle count, or `.acmplan` byte.
//! 3. **`OPENACM_FORCE_SCALAR=1` pins dispatch to the scalar tier** for
//!    the whole process — the CI matrix runs the full test suite once per
//!    dispatch arm so both stay green.

use std::sync::OnceLock;

/// Vector instruction tier a kernel can dispatch to. All variants exist on
/// every architecture (so tests and benches can name them portably); a
/// tier that the current host/arch cannot execute is simply never returned
/// by [`detect`] / [`available_levels`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar/u64 code — always compiled, the bit-exactness
    /// oracle for every vector path.
    Scalar,
    /// 256-bit AVX2 paths (x86_64 only; runtime-detected).
    Avx2,
    /// 128-bit NEON paths (aarch64 only; baseline on every aarch64 std
    /// target, still runtime-detected for uniformity).
    Neon,
}

impl SimdLevel {
    /// Short name for logs, bench JSON columns and test skip messages.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// How many `u64` bit-plane words the gate evaluator processes per net
    /// per topological sweep at this tier (one 256-bit op = 4 words, one
    /// 128-bit op = 2): the plane-group width of
    /// [`crate::gates::Netlist::eval_wide_into`].
    pub fn plane_words(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 4,
            SimdLevel::Neon => 2,
        }
    }
}

/// `OPENACM_FORCE_SCALAR=1` (any value other than empty/`0`/`false`) pins
/// every dispatch site to [`SimdLevel::Scalar`].
fn force_scalar() -> bool {
    match std::env::var("OPENACM_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"),
        Err(_) => false,
    }
}

/// The best tier this host can execute, honoring `OPENACM_FORCE_SCALAR`.
/// Cached after the first call (feature detection and the env read happen
/// once per process), so hot loops can call it freely.
pub fn detect() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let level = if force_scalar() {
            SimdLevel::Scalar
        } else {
            detect_host()
        };
        // Once per process: expose the chosen tier in the metrics registry
        // (0=scalar, 1=avx2, 2=neon) and the structured event log.
        crate::obs::gauge("simd.level").set(match level {
            SimdLevel::Scalar => 0,
            SimdLevel::Avx2 => 1,
            SimdLevel::Neon => 2,
        });
        crate::obs::info(
            "simd",
            "dispatch level selected",
            &[("level", level.name().to_string())],
        );
        level
    })
}

/// Raw host capability, ignoring the env override.
fn detect_host() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Scalar
}

/// Every tier runnable right now, scalar first — what the equivalence
/// tests iterate so each compiled vector path is checked against the
/// oracle on hosts that can run it (and skipped with a message on hosts
/// that cannot). Under `OPENACM_FORCE_SCALAR` this is `[Scalar]`, which is
/// exactly what makes the forced-scalar CI arm meaningful.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut levels = vec![SimdLevel::Scalar];
    let best = detect();
    if best != SimdLevel::Scalar {
        levels.push(best);
    }
    levels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_plane_words_are_consistent() {
        for (level, name, words) in [
            (SimdLevel::Scalar, "scalar", 1usize),
            (SimdLevel::Avx2, "avx2", 4),
            (SimdLevel::Neon, "neon", 2),
        ] {
            assert_eq!(level.name(), name);
            assert_eq!(level.plane_words(), words);
        }
    }

    #[test]
    fn detect_is_stable_and_listed() {
        let a = detect();
        let b = detect();
        assert_eq!(a, b, "cached detection must be stable");
        let levels = available_levels();
        assert_eq!(levels[0], SimdLevel::Scalar);
        assert!(levels.contains(&a));
        // The detected tier must be executable on this architecture.
        match a {
            SimdLevel::Scalar => {}
            SimdLevel::Avx2 => assert!(cfg!(target_arch = "x86_64")),
            SimdLevel::Neon => assert!(cfg!(target_arch = "aarch64")),
        }
    }
}
