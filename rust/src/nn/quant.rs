//! Static symmetric int8 quantization — bit-compatible with the Python
//! build path (`python/compile/model.py::quantize`).
//!
//! `q = clamp(round(x / scale), -127, 127)` with a per-tensor scale fixed
//! at calibration time. Products are looked up in the 256×256 LUT indexed
//! by the two int8 bit patterns; accumulation is exact i64.

/// Quantize one value.
#[inline]
pub fn quantize(x: f32, scale: f32) -> i8 {
    let q = (x / scale).round();
    q.clamp(-127.0, 127.0) as i8
}

/// Quantize a slice.
pub fn quantize_all(xs: &[f32], scale: f32) -> Vec<i8> {
    xs.iter().map(|&x| quantize(x, scale)).collect()
}

/// Calibrate a symmetric scale from data: `max|x| / 127` (never zero).
pub fn calibrate(xs: &[f32]) -> f32 {
    let m = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
    (m / 127.0).max(1e-8)
}

/// LUT lookup of an int8×int8 product.
#[inline]
pub fn lut_product(lut: &[i32], a: i8, b: i8) -> i32 {
    lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)]
}

/// Quantized matmul through the LUT: `A (m×k, int8) × B (k×n, int8)` with
/// i64 accumulation, dequantized by `scale_a * scale_b`.
pub fn lut_matmul(
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale_a: f32,
    scale_b: f32,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let s = scale_a * scale_b;
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc: i64 = 0;
            for p in 0..k {
                acc += lut_product(lut, a[i * k + p], b[p * n + j]) as i64;
            }
            out[i * n + j] = acc as f32 * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;
    use crate::mult::behavioral::int8_lut;

    #[test]
    fn quantize_roundtrip_and_clamp() {
        assert_eq!(quantize(0.0, 0.1), 0);
        assert_eq!(quantize(1.0, 0.1), 10);
        assert_eq!(quantize(-1.0, 0.1), -10);
        assert_eq!(quantize(100.0, 0.1), 127); // clamp
        assert_eq!(quantize(-100.0, 0.1), -127);
    }

    #[test]
    fn calibrate_covers_range() {
        let xs = [0.5f32, -2.0, 1.0];
        let s = calibrate(&xs);
        assert!((s - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(quantize(-2.0, s), -127);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn exact_lut_matmul_matches_float_matmul() {
        let lut = int8_lut(&MultFamily::Exact);
        // A 2x3, B 3x2 with exactly-representable values.
        let sa = 0.5f32;
        let sb = 0.25f32;
        let a_f = [1.0f32, -2.0, 3.0, 0.5, 2.5, -1.5];
        let b_f = [0.25f32, 0.5, -0.75, 1.0, 0.25, -0.5];
        let a_q = quantize_all(&a_f, sa);
        let b_q = quantize_all(&b_f, sb);
        let out = lut_matmul(&lut, &a_q, &b_q, 2, 3, 2, sa, sb);
        // reference float matmul on the dequantized values
        for i in 0..2 {
            for j in 0..2 {
                let mut r = 0f32;
                for p in 0..3 {
                    r += (a_q[i * 3 + p] as f32 * sa) * (b_q[p * 2 + j] as f32 * sb);
                }
                assert!((out[i * 2 + j] - r).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approx_lut_matmul_is_close() {
        let exact = int8_lut(&MultFamily::Exact);
        let logour = int8_lut(&MultFamily::LogOur);
        let sa = 0.02f32;
        let sb = 0.03f32;
        let a: Vec<i8> = (0..64).map(|i| ((i * 37) % 255) as i64 as i8).collect();
        let b: Vec<i8> = (0..64).map(|i| ((i * 91) % 251) as i64 as i8).collect();
        let oe = lut_matmul(&exact, &a, &b, 8, 8, 8, sa, sb);
        let ol = lut_matmul(&logour, &a, &b, 8, 8, 8, sa, sb);
        let ref_norm: f32 = oe.iter().map(|x| x.abs()).sum::<f32>() / oe.len() as f32;
        let err: f32 = oe
            .iter()
            .zip(&ol)
            .map(|(x, y)| (x - y).abs())
            .sum::<f32>()
            / oe.len() as f32;
        assert!(err > 0.0, "logour must differ from exact");
        assert!(err < 0.2 * ref_norm, "relative error too large: {err} vs {ref_norm}");
    }
}
