//! Static symmetric int8 quantization — bit-compatible with the Python
//! build path (`python/compile/model.py::quantize`).
//!
//! `q = clamp(round(x / scale), -127, 127)` with a per-tensor scale fixed
//! at calibration time. Products are looked up in the 256×256 LUT indexed
//! by the two int8 bit patterns; accumulation is exact i64.
//!
//! Two GEMM entry points share that contract:
//!
//! * [`lut_matmul`] — the scalar kernel (batch-1 serving and the tests'
//!   oracle path). It keeps the row-at-a-time structure but borrows the
//!   batched kernel's contiguous LUT-row gather and zero-activation-row
//!   skip; a truly naive triple loop lives in the tests as the ultimate
//!   reference.
//! * [`lut_matmul_batched`] — the serving kernel: tile-blocked over
//!   m/n/k, i32 inner accumulation widened into i64 per k-tile, LUT rows
//!   reused across an output row, zero-activation rows skipped when the
//!   LUT maps them to zero, and row-tiles spread over the thread pool.
//!   Its integer core is exposed as [`lut_matmul_acc`] for the compile
//!   search's delta-replay path.
//!
//! Because every partial sum is integer, any accumulation order yields
//! the same i64 total, so both kernels are *bit-identical* to the naive
//! reference for every LUT and shape
//! (`rust/tests/nn_batch_equivalence.rs`).
//!
//! ## SIMD dispatch (DESIGN.md §"SIMD kernels")
//!
//! The blocked kernel's two inner loops — the contiguous LUT-row gather
//! into the i32 strip and the i32 → i64 widening flush at each k-tile
//! boundary — dispatch at runtime through [`crate::util::simd`]: AVX2
//! (8-wide gather, 4-wide widen) on x86_64, NEON-compiled bodies on
//! aarch64, and the scalar bodies everywhere else (always compiled; they
//! are the oracle the vector paths are tested against, and
//! `OPENACM_FORCE_SCALAR=1` pins dispatch to them). Exact integer
//! accumulation makes every path bit-identical. The [`TILE_K`] i32
//! partial-sum bound is *enforced at runtime*: a hostile/degenerate LUT
//! whose entries exceed `i32::MAX / TILE_K` no longer risks silent i32
//! wrap (previously only a `debug_assert!`) — the kernel drops to an
//! i64-widened scalar strip that cannot wrap, and the serving backend
//! surfaces a warning ([`crate::runtime::backend::Backend::warnings`]).

use crate::util::simd::{self, SimdLevel};
use crate::util::threadpool::parallel_map;

/// Quantize one value.
#[inline]
pub fn quantize(x: f32, scale: f32) -> i8 {
    let q = (x / scale).round();
    q.clamp(-127.0, 127.0) as i8
}

/// Quantize a slice.
pub fn quantize_all(xs: &[f32], scale: f32) -> Vec<i8> {
    xs.iter().map(|&x| quantize(x, scale)).collect()
}

/// Calibrate a symmetric scale from data: `max|x| / 127` (never zero).
pub fn calibrate(xs: &[f32]) -> f32 {
    let m = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
    (m / 127.0).max(1e-8)
}

/// LUT lookup of an int8×int8 product.
#[inline]
pub fn lut_product(lut: &[i32], a: i8, b: i8) -> i32 {
    lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)]
}

/// Quantized matmul through the LUT: `A (m×k, int8) × B (k×n, int8)` with
/// i64 accumulation, dequantized by `scale_a * scale_b`.
///
/// This is the scalar (batch-1 / oracle) kernel, but it shares the two
/// cheap structural wins of [`lut_matmul_batched`]: each A element selects
/// one contiguous 256-entry LUT row reused across the whole B row (a
/// sequential gather instead of strided 256 KiB-wide lookups), and rows
/// whose A element is zero are skipped when the LUT's zero row is all
/// zeros (true for every real multiplier family; after ReLU that is a
/// large fraction of all activations). Both are bit-identity-preserving:
/// each output element still accumulates exactly the same i64 products
/// (integer addition is order-independent, and the skipped terms are
/// exact zeros), and the final `acc as f32 * s` op is unchanged. The
/// in-module tests pin this against a naive triple-loop reference.
pub fn lut_matmul(
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale_a: f32,
    scale_b: f32,
) -> Vec<f32> {
    assert_eq!(lut.len(), 65536);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let s = scale_a * scale_b;
    let zero_row_is_zero = lut[..256].iter().all(|&v| v == 0);
    let mut out = vec![0f32; m * n];
    let mut acc = vec![0i64; n];
    for i in 0..m {
        acc.fill(0);
        let a_row = &a[i * k..(i + 1) * k];
        for (p, &av) in a_row.iter().enumerate() {
            if av == 0 && zero_row_is_zero {
                continue;
            }
            let lut_row = &lut[((av as u8 as usize) << 8)..][..256];
            let b_row = &b[p * n..(p + 1) * n];
            for (o, &bv) in acc.iter_mut().zip(b_row) {
                *o += lut_row[bv as u8 as usize] as i64;
            }
        }
        for (j, &v) in acc.iter().enumerate() {
            out[i * n + j] = v as f32 * s;
        }
    }
    out
}

/// Output-row tile: one parallel work unit; `TILE_M × n` i64 accumulators
/// stay resident (≤ 16 KiB for n ≤ 64).
const TILE_M: usize = 32;
/// Reduction tile: at most `TILE_K` products accumulate in i32 before the
/// widening flush. Worst case `128 × 127 × 127 ≈ 2.1e6` — four orders of
/// magnitude inside i32 range, so the narrow accumulator can never wrap.
const TILE_K: usize = 128;
/// Column tile: bounds the i32 partial-sum strip (`TILE_N × 4 B` in L1).
const TILE_N: usize = 64;

/// Blocked, batched LUT-GEMM: `A (m×k, int8) × B (k×n, int8)` with the
/// same contract as [`lut_matmul`] and bit-identical output.
///
/// Layout of the hot loop: for each (row-tile, k-tile, n-tile), walk one
/// output row at a time; each A element selects a contiguous 256-entry LUT
/// row that is reused across the whole B row slice (n-tile wide,
/// contiguous), so the inner loop is a sequential gather instead of the
/// reference's strided 256 KiB-wide lookups. Rows whose A element is zero
/// are skipped entirely when the LUT's zero row is all zeros (true for the
/// exact multiplier and cheap to test once) — after ReLU that is a large
/// fraction of all activations.
///
/// `threads` spreads row-tiles across scoped workers (1 = fully serial);
/// the result is independent of the thread count. The inner strip loops
/// dispatch through [`crate::util::simd::detect`]; use
/// [`lut_matmul_batched_with`] to pin a level explicitly.
///
/// LUT entries are scanned once against the blocked kernel's i32
/// partial-sum bound (`|entry| ≤ i32::MAX / 128`, see
/// [`lut_exceeds_blocked_bound`]). Every real int8 product LUT is bounded
/// by 128·128 = 16384, four orders of magnitude inside the limit; a
/// hostile LUT that exceeds it is routed to an i64-widened scalar strip
/// instead of silently wrapping, so the output stays bit-identical to the
/// reference for *every* LUT.
#[allow(clippy::too_many_arguments)]
pub fn lut_matmul_batched(
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale_a: f32,
    scale_b: f32,
    threads: usize,
) -> Vec<f32> {
    lut_matmul_batched_with(simd::detect(), lut, a, b, m, k, n, scale_a, scale_b, threads)
}

/// [`lut_matmul_batched`] with an explicit [`SimdLevel`] instead of the
/// auto-detected one. A level the host cannot execute falls back to
/// scalar; the output is bit-identical across levels either way. Public
/// for the equivalence tests and benches.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn lut_matmul_batched_with(
    level: SimdLevel,
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale_a: f32,
    scale_b: f32,
    threads: usize,
) -> Vec<f32> {
    let tiles = lut_gemm_tiles(level, lut, a, b, m, k, n, threads);
    let s = scale_a * scale_b;
    let mut out = vec![0f32; m * n];
    for (t, acc) in tiles.into_iter().enumerate() {
        let base = t * TILE_M * n;
        for (off, v) in acc.into_iter().enumerate() {
            // Identical final op to the reference: `acc as f32 * s`.
            out[base + off] = v as f32 * s;
        }
    }
    out
}

/// Integer core of [`lut_matmul_batched`]: the raw i64 accumulators of
/// `A (m×k) × B (k×n)` through `lut`, before dequantization. Exposed so
/// the compile search's incremental evaluator can keep a baseline's exact
/// accumulators and patch them with sparse integer deltas
/// ([`crate::nn::model::QuantCnn::delta_resume_exact`]); every accumulator
/// is the exact integer sum of its products, so the value is independent
/// of tiling and thread count.
pub fn lut_matmul_acc(
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<i64> {
    lut_matmul_acc_with(simd::detect(), lut, a, b, m, k, n, threads)
}

/// [`lut_matmul_acc`] with an explicit [`SimdLevel`]. Public for the
/// equivalence tests and benches.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn lut_matmul_acc_with(
    level: SimdLevel,
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<i64> {
    let tiles = lut_gemm_tiles(level, lut, a, b, m, k, n, threads);
    let mut out = vec![0i64; m * n];
    for (t, acc) in tiles.into_iter().enumerate() {
        let base = t * TILE_M * n;
        out[base..base + acc.len()].copy_from_slice(&acc);
    }
    out
}

/// True iff some LUT entry's magnitude exceeds `i32::MAX / TILE_K`, the
/// bound that keeps a k-tile's i32 partial sum from wrapping in the
/// blocked kernel. No real int8 product LUT comes close (|a·b| ≤ 16384 ≪
/// ≈16.8M); when a synthetic/hostile LUT does, the blocked kernel
/// transparently switches to an i64-widened scalar strip and the serving
/// backend reports it via `Backend::warnings`.
pub fn lut_exceeds_blocked_bound(lut: &[i32]) -> bool {
    let bound = i32::MAX / TILE_K as i32;
    lut.iter().any(|&v| v < -bound || v > bound)
}

/// The shared blocked-GEMM core: one i64 accumulator block per row tile
/// ([`TILE_M`] rows each, the last possibly short), computed across the
/// thread pool. Callers stitch/dequantize in a single pass.
///
/// The tail tiles need no special-casing: every `min(...)` clamp above
/// produces a short strip/row slice, and both the scalar and vector
/// strip bodies take the live `width` explicitly (the vector bodies
/// handle the sub-vector remainder with a scalar tail loop), so
/// non-multiple m/k/n shapes walk exactly the same element set as the
/// reference (`rust/tests/nn_batch_equivalence.rs` pins odd shapes per
/// level).
#[allow(clippy::too_many_arguments)]
fn lut_gemm_tiles(
    level: SimdLevel,
    lut: &[i32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<Vec<i64>> {
    assert_eq!(lut.len(), 65536);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    // Runtime guard (was a debug_assert, i.e. a silent i32 wrap in
    // release): a LUT outside the i32 partial-sum bound takes the
    // i64-widened scalar strip below, which cannot wrap for any i32
    // entries (|entry| ≤ 2³¹ summed ≤ 2¹⁷ times fits i64 with > 15 bits
    // to spare even before the per-tile flush).
    let wide_acc = lut_exceeds_blocked_bound(lut);
    // a == 0 contributes nothing iff the LUT's zero row is identically
    // zero; skipping it then adds the same zeros the reference adds.
    let zero_row_is_zero = lut[..256].iter().all(|&v| v == 0);
    let row_tiles = m.div_ceil(TILE_M);
    // Dispatch accounting at the GEMM boundary, never inside strip loops:
    // one registry touch per call regardless of shape.
    crate::obs::record_gemm_dispatch(
        wide_acc,
        (m as u64) * k.div_ceil(TILE_K) as u64 * n.div_ceil(TILE_N) as u64,
    );
    parallel_map(row_tiles, threads, |t| {
        let i0 = t * TILE_M;
        let i1 = (i0 + TILE_M).min(m);
        let mut acc = vec![0i64; (i1 - i0) * n];
        let mut strip = [0i32; TILE_N];
        let mut strip64 = [0i64; TILE_N];
        for k0 in (0..k).step_by(TILE_K) {
            let k1 = (k0 + TILE_K).min(k);
            for j0 in (0..n).step_by(TILE_N) {
                let j1 = (j0 + TILE_N).min(n);
                let width = j1 - j0;
                for i in i0..i1 {
                    let a_row = &a[i * k..(i + 1) * k];
                    let out_row = &mut acc[(i - i0) * n + j0..(i - i0) * n + j1];
                    if wide_acc {
                        // Overflow-proof path for out-of-bound LUTs:
                        // accumulate straight into i64, scalar only.
                        let partial = &mut strip64[..width];
                        partial.fill(0);
                        for p in k0..k1 {
                            let av = a_row[p];
                            if av == 0 && zero_row_is_zero {
                                continue;
                            }
                            let lut_row = &lut[((av as u8 as usize) << 8)..][..256];
                            let b_row = &b[p * n + j0..p * n + j1];
                            for (ps, &bv) in partial.iter_mut().zip(b_row) {
                                *ps += lut_row[bv as u8 as usize] as i64;
                            }
                        }
                        for (o, &ps) in out_row.iter_mut().zip(partial.iter()) {
                            *o += ps;
                        }
                        continue;
                    }
                    let partial = &mut strip[..width];
                    partial.fill(0);
                    for p in k0..k1 {
                        let av = a_row[p];
                        if av == 0 && zero_row_is_zero {
                            continue;
                        }
                        let lut_row = &lut[((av as u8 as usize) << 8)..][..256];
                        let b_row = &b[p * n + j0..p * n + j1];
                        strip_accum(level, lut_row, b_row, partial);
                    }
                    widen_accum(level, out_row, partial);
                }
            }
        }
        acc
    })
}

/// `partial[j] += lut_row[b_row[j] as u8]` over the live strip width,
/// dispatched on `level`. The scalar body is always compiled and is the
/// oracle; a level the host lacks (or a cross-arch level) falls through
/// to it. Exact i32 adds ⇒ bit-identical across levels.
#[inline]
fn strip_accum(level: SimdLevel, lut_row: &[i32], b_row: &[i8], partial: &mut [i32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 availability just verified on this host.
                unsafe { avx2::strip_accum(lut_row, b_row, partial) };
                return;
            }
            strip_accum_scalar(lut_row, b_row, partial);
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                // SAFETY: NEON availability just verified on this host.
                unsafe { neon::strip_accum(lut_row, b_row, partial) };
                return;
            }
            strip_accum_scalar(lut_row, b_row, partial);
        }
        _ => strip_accum_scalar(lut_row, b_row, partial),
    }
}

/// `out_row[j] += partial[j] as i64` over the live strip width — the
/// k-tile-boundary widening flush — dispatched on `level` like
/// [`strip_accum`].
#[inline]
fn widen_accum(level: SimdLevel, out_row: &mut [i64], partial: &[i32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 availability just verified on this host.
                unsafe { avx2::widen_accum(out_row, partial) };
                return;
            }
            widen_accum_scalar(out_row, partial);
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                // SAFETY: NEON availability just verified on this host.
                unsafe { neon::widen_accum(out_row, partial) };
                return;
            }
            widen_accum_scalar(out_row, partial);
        }
        _ => widen_accum_scalar(out_row, partial),
    }
}

#[inline(always)]
fn strip_accum_scalar(lut_row: &[i32], b_row: &[i8], partial: &mut [i32]) {
    for (ps, &bv) in partial.iter_mut().zip(b_row) {
        *ps += lut_row[bv as u8 as usize];
    }
}

#[inline(always)]
fn widen_accum_scalar(out_row: &mut [i64], partial: &[i32]) {
    for (o, &ps) in out_row.iter_mut().zip(partial.iter()) {
        *o += ps as i64;
    }
}

/// AVX2 strip bodies. Private; reached only through the dispatchers
/// above after a runtime `is_x86_feature_detected!("avx2")` check.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// 8-wide gathered `partial += lut_row[b_row as u8]`.
    ///
    /// # Safety
    /// Requires AVX2. Slice accesses stay in bounds: each 8-lane block
    /// loads 8 bytes of `b_row` and reads/writes 8 i32 of `partial`
    /// within `len`, and every gather index is a zero-extended byte
    /// (< 256 = `lut_row.len()`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn strip_accum(lut_row: &[i32], b_row: &[i8], partial: &mut [i32]) {
        let len = partial.len().min(b_row.len());
        let mut j = 0usize;
        while j + 8 <= len {
            // 8 int8 indices → zero-extend to 8 u32 lanes.
            let idx8 = _mm_loadl_epi64(b_row.as_ptr().add(j) as *const __m128i);
            let idx = _mm256_cvtepu8_epi32(idx8);
            // Gather lut_row[idx] (scale 4 = i32 stride); the LUT row is
            // a contiguous 256-entry slice so all lanes hit cache lines
            // already touched by neighboring strips.
            let gathered = _mm256_i32gather_epi32::<4>(lut_row.as_ptr(), idx);
            let ps = _mm256_loadu_si256(partial.as_ptr().add(j) as *const __m256i);
            let sum = _mm256_add_epi32(ps, gathered);
            _mm256_storeu_si256(partial.as_mut_ptr().add(j) as *mut __m256i, sum);
            j += 8;
        }
        // Scalar tail (< 8 lanes) — same adds, same order.
        for jj in j..len {
            partial[jj] += lut_row[b_row[jj] as u8 as usize];
        }
    }

    /// 4-wide widening flush `out_row += partial as i64`.
    ///
    /// # Safety
    /// Requires AVX2. Each 4-lane block reads 4 i32 and reads/writes
    /// 4 i64 within `len`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn widen_accum(out_row: &mut [i64], partial: &[i32]) {
        let len = out_row.len().min(partial.len());
        let mut j = 0usize;
        while j + 4 <= len {
            let ps = _mm_loadu_si128(partial.as_ptr().add(j) as *const __m128i);
            let wide = _mm256_cvtepi32_epi64(ps);
            let o = _mm256_loadu_si256(out_row.as_ptr().add(j) as *const __m256i);
            let sum = _mm256_add_epi64(o, wide);
            _mm256_storeu_si256(out_row.as_mut_ptr().add(j) as *mut __m256i, sum);
            j += 4;
        }
        for jj in j..len {
            out_row[jj] += partial[jj] as i64;
        }
    }
}

/// NEON strip bodies: the scalar loops recompiled inside a
/// `target_feature(enable = "neon")` scope so LLVM auto-vectorizes them
/// (tbl-free gather stays scalar but the adds/widens vectorize). Private;
/// reached only through the dispatchers after a runtime NEON check.
#[cfg(target_arch = "aarch64")]
mod neon {
    /// # Safety
    /// Requires NEON (checked by the caller). Body is safe Rust.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn strip_accum(lut_row: &[i32], b_row: &[i8], partial: &mut [i32]) {
        super::strip_accum_scalar(lut_row, b_row, partial);
    }

    /// # Safety
    /// Requires NEON (checked by the caller). Body is safe Rust.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn widen_accum(out_row: &mut [i64], partial: &[i32]) {
        super::widen_accum_scalar(out_row, partial);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;
    use crate::mult::behavioral::int8_lut;

    #[test]
    fn quantize_roundtrip_and_clamp() {
        assert_eq!(quantize(0.0, 0.1), 0);
        assert_eq!(quantize(1.0, 0.1), 10);
        assert_eq!(quantize(-1.0, 0.1), -10);
        assert_eq!(quantize(100.0, 0.1), 127); // clamp
        assert_eq!(quantize(-100.0, 0.1), -127);
    }

    #[test]
    fn calibrate_covers_range() {
        let xs = [0.5f32, -2.0, 1.0];
        let s = calibrate(&xs);
        assert!((s - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(quantize(-2.0, s), -127);
    }

    /// The truly naive triple loop — the ultimate oracle now that
    /// [`lut_matmul`] itself gathers LUT rows and skips zero rows.
    fn naive_lut_matmul(
        lut: &[i32],
        a: &[i8],
        b: &[i8],
        m: usize,
        k: usize,
        n: usize,
        sa: f32,
        sb: f32,
    ) -> Vec<f32> {
        let s = sa * sb;
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc: i64 = 0;
                for p in 0..k {
                    acc += lut_product(lut, a[i * k + p], b[p * n + j]) as i64;
                }
                out[i * n + j] = acc as f32 * s;
            }
        }
        out
    }

    #[test]
    fn scalar_kernel_matches_naive_reference() {
        // Covers both skip regimes: a LUT with a non-zero zero row (skip
        // disabled) and the exact-product LUT with zero-heavy A (skip hot).
        let mut shifted = vec![0i32; 65536];
        for x in -128i32..=127 {
            for y in -128i32..=127 {
                shifted[(((x as u8) as usize) << 8) | ((y as u8) as usize)] = x * y + 1;
            }
        }
        let exact = int8_lut(&MultFamily::Exact);
        let a: Vec<i8> = (0..48)
            .map(|i| if i % 4 == 0 { 0 } else { ((i * 89 + 3) % 256) as u8 as i8 })
            .collect();
        let b: Vec<i8> = (0..36).map(|i| ((i * 57 + 11) % 256) as u8 as i8).collect();
        for lut in [&shifted, &exact] {
            for (m, k, n) in [(8, 6, 6), (4, 12, 3), (1, 36, 1)] {
                let fast = lut_matmul(lut, &a[..m * k], &b[..k * n], m, k, n, 0.1, 0.2);
                let naive = naive_lut_matmul(lut, &a[..m * k], &b[..k * n], m, k, n, 0.1, 0.2);
                assert_eq!(fast, naive, "m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn acc_kernel_is_exact_integer_sum() {
        let lut = int8_lut(&MultFamily::Exact);
        let a: Vec<i8> = (0..24).map(|i| ((i * 37) % 255) as u8 as i8).collect();
        let b: Vec<i8> = (0..18).map(|i| ((i * 91) % 251) as u8 as i8).collect();
        for threads in [1, 2] {
            let acc = lut_matmul_acc(&lut, &a, &b, 4, 6, 3, threads);
            for i in 0..4 {
                for j in 0..3 {
                    let want: i64 = (0..6)
                        .map(|p| (a[i * 6 + p] as i64) * (b[p * 3 + j] as i64))
                        .sum();
                    assert_eq!(acc[i * 3 + j], want, "({i},{j}) threads={threads}");
                }
            }
        }
    }

    #[test]
    fn batched_matches_reference_on_small_odd_shape() {
        // Tiny LUT-shaped check that runs even in debug: a synthetic
        // "multiplier" LUT (a*b + 1 so the zero row is non-zero and the
        // zero-skip stays disabled) over a 5×7×3 GEMM.
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b + 1;
            }
        }
        let a: Vec<i8> = (0..35).map(|i| ((i * 89 + 3) % 256) as u8 as i8).collect();
        let b: Vec<i8> = (0..21).map(|i| ((i * 57 + 11) % 256) as u8 as i8).collect();
        let reference = lut_matmul(&lut, &a, &b, 5, 7, 3, 0.1, 0.2);
        for threads in [1, 3] {
            let fast = lut_matmul_batched(&lut, &a, &b, 5, 7, 3, 0.1, 0.2, threads);
            assert_eq!(fast, reference, "threads={threads}");
        }
    }

    #[test]
    fn batched_zero_skip_is_exact() {
        // Zero row all-zero (exact multiplier semantics) + zero-heavy A:
        // the skip path must add exactly the zeros the reference adds.
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let a: Vec<i8> = (0..40).map(|i| if i % 3 == 0 { 0 } else { (i % 120) as i8 - 60 }).collect();
        let b: Vec<i8> = (0..50).map(|i| ((i * 7) % 256) as u8 as i8).collect();
        let reference = lut_matmul(&lut, &a, &b, 8, 5, 10, 0.5, 0.5);
        let fast = lut_matmul_batched(&lut, &a, &b, 8, 5, 10, 0.5, 0.5, 2);
        assert_eq!(fast, reference);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn exact_lut_matmul_matches_float_matmul() {
        let lut = int8_lut(&MultFamily::Exact);
        // A 2x3, B 3x2 with exactly-representable values.
        let sa = 0.5f32;
        let sb = 0.25f32;
        let a_f = [1.0f32, -2.0, 3.0, 0.5, 2.5, -1.5];
        let b_f = [0.25f32, 0.5, -0.75, 1.0, 0.25, -0.5];
        let a_q = quantize_all(&a_f, sa);
        let b_q = quantize_all(&b_f, sb);
        let out = lut_matmul(&lut, &a_q, &b_q, 2, 3, 2, sa, sb);
        // reference float matmul on the dequantized values
        for i in 0..2 {
            for j in 0..2 {
                let mut r = 0f32;
                for p in 0..3 {
                    r += (a_q[i * 3 + p] as f32 * sa) * (b_q[p * 2 + j] as f32 * sb);
                }
                assert!((out[i * 2 + j] - r).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approx_lut_matmul_is_close() {
        let exact = int8_lut(&MultFamily::Exact);
        let logour = int8_lut(&MultFamily::LogOur);
        let sa = 0.02f32;
        let sb = 0.03f32;
        let a: Vec<i8> = (0..64).map(|i| ((i * 37) % 255) as i64 as i8).collect();
        let b: Vec<i8> = (0..64).map(|i| ((i * 91) % 251) as i64 as i8).collect();
        let oe = lut_matmul(&exact, &a, &b, 8, 8, 8, sa, sb);
        let ol = lut_matmul(&logour, &a, &b, 8, 8, 8, sa, sb);
        let ref_norm: f32 = oe.iter().map(|x| x.abs()).sum::<f32>() / oe.len() as f32;
        let err: f32 = oe
            .iter()
            .zip(&ol)
            .map(|(x, y)| (x - y).abs())
            .sum::<f32>()
            / oe.len() as f32;
        assert!(err > 0.0, "logour must differ from exact");
        assert!(err < 0.2 * ref_norm, "relative error too large: {err} vs {ref_norm}");
    }

    #[test]
    fn every_simd_level_matches_scalar_on_odd_shapes() {
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b + 1;
            }
        }
        let levels = crate::util::simd::available_levels();
        // Shapes straddling every tile boundary: sub-tile, exact-tile,
        // tile+1 in each of m/k/n, plus the degenerate 1×1×1.
        for (m, k, n) in [(1, 1, 1), (5, 7, 3), (33, 129, 65), (32, 128, 64), (2, 200, 9)] {
            let a: Vec<i8> = (0..m * k).map(|i| ((i * 89 + 3) % 256) as u8 as i8).collect();
            let b: Vec<i8> = (0..k * n).map(|i| ((i * 57 + 11) % 256) as u8 as i8).collect();
            let oracle = lut_matmul(&lut, &a, &b, m, k, n, 0.1, 0.2);
            for &level in &levels {
                let got =
                    lut_matmul_batched_with(level, &lut, &a, &b, m, k, n, 0.1, 0.2, 2);
                assert_eq!(got, oracle, "level={} m={m} k={k} n={n}", level.name());
            }
        }
    }

    #[test]
    fn hostile_lut_beyond_bound_takes_exact_widened_path() {
        // Entries at the extremes of i32: a single k-tile of 128 same-sign
        // products would wrap an i32 partial sum ~60× over. Before the
        // runtime guard this silently wrapped in release builds.
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                let sign = if (a ^ b) < 0 { -1i64 } else { 1 };
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] =
                    (sign * (i32::MAX as i64 - (a.unsigned_abs() * b.unsigned_abs()) as i64))
                        as i32;
            }
        }
        assert!(lut_exceeds_blocked_bound(&lut));
        assert!(!lut_exceeds_blocked_bound(&int8_lut(&MultFamily::Exact)));
        let (m, k, n) = (3, 300, 5);
        let a: Vec<i8> = (0..m * k).map(|i| if i % 2 == 0 { 127 } else { -128 }).collect();
        let b: Vec<i8> = (0..k * n).map(|i| ((i * 13 + 1) % 256) as u8 as i8).collect();
        let oracle = lut_matmul(&lut, &a, &b, m, k, n, 1.0, 1.0);
        for &level in &crate::util::simd::available_levels() {
            let got = lut_matmul_batched_with(level, &lut, &a, &b, m, k, n, 1.0, 1.0, 2);
            assert_eq!(got, oracle, "level={}", level.name());
            // f32 rounding of huge i64 sums can collide, so also compare
            // the raw accumulators against a direct naive i64 reduction.
            let acc = lut_matmul_acc_with(level, &lut, &a, &b, m, k, n, 1);
            for i in 0..m {
                for j in 0..n {
                    let direct: i64 = (0..k)
                        .map(|p| lut_product(&lut, a[i * k + p], b[p * n + j]) as i64)
                        .sum();
                    assert_eq!(acc[i * n + j], direct, "acc ({i},{j})");
                }
            }
        }
    }
}
