//! Top-1 / Top-5 accuracy scoring (Table IV's metrics).

/// Accuracy result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub top1: f64,
    pub top5: f64,
    pub n: usize,
}

/// Score a batch of logits rows against labels.
pub fn topk_accuracy(logits: &[Vec<f32>], labels: &[usize]) -> EvalResult {
    assert_eq!(logits.len(), labels.len());
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for (row, &label) in logits.iter().zip(labels) {
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        if idx[0] == label {
            top1 += 1;
        }
        if idx.iter().take(5).any(|&i| i == label) {
            top5 += 1;
        }
    }
    EvalResult {
        top1: top1 as f64 / labels.len().max(1) as f64,
        top5: top5 as f64 / labels.len().max(1) as f64,
        n: labels.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_and_near_miss() {
        let logits = vec![
            vec![0.1, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], // top1 = 1
            vec![0.5, 0.4, 0.3, 0.2, 0.15, 0.1, 0.0, 0.0, 0.0, 0.0], // label 4 in top5
        ];
        let r = topk_accuracy(&logits, &[1, 4]);
        assert_eq!(r.top1, 0.5);
        assert_eq!(r.top5, 1.0);
    }

    #[test]
    fn top5_contains_top1() {
        let logits: Vec<Vec<f32>> = (0..20)
            .map(|i| (0..10).map(|j| ((i * j) % 7) as f32).collect())
            .collect();
        let labels: Vec<usize> = (0..20).map(|i| i % 10).collect();
        let r = topk_accuracy(&logits, &labels);
        assert!(r.top5 >= r.top1);
    }
}
