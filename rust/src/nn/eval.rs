//! Top-1 / Top-5 accuracy scoring (Table IV's metrics).

/// Index of the largest logit under **total ordering** — the one argmax
/// every consumer (server responses, workload labels, accuracy scoring)
/// must share so ties and NaNs break identically everywhere. NaN sorts
/// above +inf and wins; an empty slice maps to class 0.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Accuracy result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalResult {
    pub top1: f64,
    pub top5: f64,
    pub n: usize,
}

/// Score a batch of logits rows against labels.
pub fn topk_accuracy(logits: &[Vec<f32>], labels: &[usize]) -> EvalResult {
    assert_eq!(logits.len(), labels.len());
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for (row, &label) in logits.iter().zip(labels) {
        let mut idx: Vec<usize> = (0..row.len()).collect();
        // Total ordering: NaN logits (e.g. from a corrupted LUT or an
        // overflowing backend) must score as a wrong answer, not panic the
        // whole evaluation. Under `total_cmp`, NaN sorts above +inf, and
        // the stable sort keeps ties (all-NaN rows) in index order.
        idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
        if idx[0] == label {
            top1 += 1;
        }
        if idx.iter().take(5).any(|&i| i == label) {
            top5 += 1;
        }
    }
    EvalResult {
        top1: top1 as f64 / labels.len().max(1) as f64,
        top5: top5 as f64 / labels.len().max(1) as f64,
        n: labels.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_and_near_miss() {
        let logits = vec![
            vec![0.1, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], // top1 = 1
            vec![0.5, 0.4, 0.3, 0.2, 0.15, 0.1, 0.0, 0.0, 0.0, 0.0], // label 4 in top5
        ];
        let r = topk_accuracy(&logits, &[1, 4]);
        assert_eq!(r.top1, 0.5);
        assert_eq!(r.top5, 1.0);
    }

    #[test]
    fn argmax_total_order() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[]), 0);
        // NaN handled via total order — wins, no panic.
        assert_eq!(argmax(&[0.1, f32::NAN, 0.5]), 1);
        // `max_by` returns the last of equal maxima — pin the tie-break.
        assert_eq!(argmax(&[1.0, 1.0]), 1);
    }

    #[test]
    fn nan_logits_score_without_panicking() {
        // Regression: this used to hit `partial_cmp().unwrap()` and panic.
        let mut poisoned = vec![f32::NAN, 0.9, 0.8, 0.7, 0.6, 0.5, 0.0, 0.0, 0.0, 0.0];
        let all_nan = vec![f32::NAN; 10];
        let r = topk_accuracy(&[poisoned.clone(), all_nan], &[1, 0]);
        assert_eq!(r.n, 2);
        // Row 0: the NaN wins top-1 under total order, so label 1 is a
        // top-1 miss but still inside top-5. Row 1: stable sort keeps the
        // all-NaN tie in index order, so index 0 == label 0.
        assert_eq!(r.top1, 0.5);
        assert_eq!(r.top5, 1.0);
        // -NaN sorts *below* everything; label 1 then wins top-1 outright.
        poisoned[0] = -f32::NAN;
        let r2 = topk_accuracy(&[poisoned], &[1]);
        assert_eq!(r2.top1, 1.0);
    }

    #[test]
    fn top5_contains_top1() {
        let logits: Vec<Vec<f32>> = (0..20)
            .map(|i| (0..10).map(|j| ((i * j) % 7) as f32).collect())
            .collect();
        let labels: Vec<usize> = (0..20).map(|i| i % 10).collect();
        let r = topk_accuracy(&logits, &labels);
        assert!(r.top5 >= r.top1);
    }
}
