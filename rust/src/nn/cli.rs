//! `openacm nn` — reproduce Table IV: Top-1/Top-5 + NMED/MRED per
//! multiplier family on the quantized CNN.
//!
//! Two execution paths over the same artifacts:
//! * `--engine native` (default) — the Rust-native quantized forward;
//! * `--engine pjrt` — the AOT JAX graph through the PJRT runtime (the
//!   production path; also used by `openacm serve`).

use anyhow::{Context, Result};
use std::path::Path;

use super::eval::{topk_accuracy, EvalResult};
use super::model::QuantCnn;
use crate::bench::harness::{sci, Table};
use crate::config::spec::MultFamily;
use crate::mult::behavioral::paper_families;
use crate::mult::error_metrics;
use crate::runtime::{client, ArtifactStore};
use crate::util::cli::Args;

/// One Table IV row.
#[derive(Clone, Debug)]
pub struct NnRow {
    pub family: String,
    pub result: EvalResult,
    pub nmed: f64,
    pub mred: f64,
}

/// Evaluate all families natively over `limit` test images — batched
/// through the blocked LUT-GEMM kernel (bit-identical to the per-image
/// scalar forward, at batch speed).
pub fn eval_native(store: &ArtifactStore, limit: usize) -> Result<Vec<NnRow>> {
    let cnn = QuantCnn::load(&store.dir)?;
    let n = store.n_images.min(limit);
    let threads = crate::util::threadpool::ThreadPool::default_parallelism();
    let views: Vec<&[u8]> = (0..n).map(|i| store.image(i)).collect();
    let mut rows = Vec::new();
    for (name, family) in paper_families() {
        let lut = store
            .luts
            .get(&name)
            .with_context(|| format!("missing LUT {name}"))?;
        let mut logits = Vec::with_capacity(n);
        for chunk in views.chunks(64) {
            logits.extend(cnn.forward_batch(lut, chunk, threads));
        }
        let result = topk_accuracy(&logits, &store.labels[..n]);
        let (nmed, mred) = family_error(&family);
        rows.push(NnRow {
            family: family.paper_label().to_string(),
            result,
            nmed,
            mred,
        });
    }
    Ok(rows)
}

/// Evaluate all families through the PJRT-compiled AOT graph.
pub fn eval_pjrt(store: &ArtifactStore, limit: usize) -> Result<Vec<NnRow>> {
    let rt = crate::runtime::Runtime::cpu()?;
    let model = rt.compile_hlo_text(&store.model_hlo)?;
    let n = store.n_images.min(limit);
    let b = store.batch;
    let weight_lits = client::weight_literals(&store.weights)?;
    let mut rows = Vec::new();
    for (name, family) in paper_families() {
        let lut = store
            .luts
            .get(&name)
            .with_context(|| format!("missing LUT {name}"))?;
        let lut_lit = client::literal_i32(&[65536], lut)?;
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut i = 0;
        while i < n {
            let take = (n - i).min(b);
            // Pad the batch with the last image.
            let mut batch_px = vec![0i32; b * 256];
            for j in 0..b {
                let src = store.image((i + j).min(n - 1));
                for (k, &p) in src.iter().enumerate() {
                    batch_px[j * 256 + k] = p as i32;
                }
            }
            let img_lit = client::literal_i32(&[b, 16, 16], &batch_px)?;
            let mut args = vec![img_lit, lut_lit.clone()];
            args.extend(weight_lits.iter().cloned());
            let out = model.run_f32(&args, b * 10)?;
            for j in 0..take {
                logits.push(out[j * 10..(j + 1) * 10].to_vec());
            }
            i += take;
        }
        let result = topk_accuracy(&logits, &store.labels[..n]);
        let (nmed, mred) = family_error(&family);
        rows.push(NnRow {
            family: family.paper_label().to_string(),
            result,
            nmed,
            mred,
        });
    }
    Ok(rows)
}

fn family_error(family: &MultFamily) -> (f64, f64) {
    match family {
        MultFamily::Exact | MultFamily::AdderTree => (0.0, 0.0),
        _ => {
            let r = error_metrics::exhaustive(family, 8);
            (r.nmed, r.mred)
        }
    }
}

/// Render Table IV.
pub fn render_table4(rows: &[NnRow]) -> Table {
    let mut t = Table::new(
        "Table IV: approximate multipliers on the quantized CNN",
        &["Multiplier", "Top-1", "Top-5", "NMED", "MRED"],
    );
    for r in rows {
        let (nmed, mred) = if r.nmed == 0.0 {
            ("-".to_string(), "-".to_string())
        } else {
            (sci(r.nmed), sci(r.mred))
        };
        t.row(&[
            r.family.clone(),
            format!("{:.3}", r.result.top1),
            format!("{:.3}", r.result.top5),
            nmed,
            mred,
        ]);
    }
    t
}

pub fn cmd_nn(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Path::new)
        .map(Path::to_path_buf)
        .unwrap_or_else(ArtifactStore::default_dir);
    let store = ArtifactStore::load(&dir)?;
    let limit = args.usize_or("limit", 512)?;
    let rows = match args.str_or("engine", "native") {
        "pjrt" => eval_pjrt(&store, limit)?,
        _ => eval_native(&store, limit)?,
    };
    render_table4(&rows).print();
    println!(
        "\npaper reference (ResNet-18/ImageNet): Exact .677/.873, Appro4-2 .668/.880,\n\
         Log-our .680/.870, LM .610/.842; NMED appro << logour << lm"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let rows = vec![NnRow {
            family: "Exact".into(),
            result: EvalResult {
                top1: 0.9,
                top5: 1.0,
                n: 100,
            },
            nmed: 0.0,
            mred: 0.0,
        }];
        let s = render_table4(&rows).render();
        assert!(s.contains("Exact"));
        assert!(s.contains("0.900"));
        assert!(s.contains("-"));
    }
}
