//! Rust-native quantized CNN forward — the mirror of
//! `python/compile/model.py` (same architecture, same static quantization,
//! same LUT-routed multiplies). Used to cross-check the AOT JAX graph and
//! as a fallback evaluator when PJRT artifacts are absent.
//!
//! Architecture (16×16×1 input, 10 classes):
//!   conv3x3(1→8) + relu + maxpool2 → conv3x3(8→16) + relu + maxpool2
//!   → flatten(2·2·16=64)… wait: 16→14→7→5→2 — flatten 2×2×16 = 64
//!   → fc(64→32) + relu → fc(32→10).

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::quant::{lut_matmul, lut_matmul_batched, quantize, quantize_all};
use crate::util::npy;
use crate::util::threadpool::parallel_map;

/// One quantized layer: int8 weights + scales.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    /// Quantized weights, layout documented per use.
    pub w_q: Vec<i8>,
    pub w_scale: f32,
    /// Input activation scale (calibrated).
    pub in_scale: f32,
    /// float bias.
    pub bias: Vec<f32>,
}

/// The full quantized CNN.
#[derive(Clone, Debug)]
pub struct QuantCnn {
    /// conv1: [out=8, in=1, 3, 3] flattened as (9) × 8 matrix after im2col.
    pub conv1: QuantLayer,
    /// conv2: [out=16, in=8, 3, 3] → (72) × 16.
    pub conv2: QuantLayer,
    /// fc1: 64 × 32.
    pub fc1: QuantLayer,
    /// fc2: 32 × 10.
    pub fc2: QuantLayer,
}

pub const IMG: usize = 16;
pub const C1_OUT: usize = 8;
pub const C2_OUT: usize = 16;
pub const FC1_OUT: usize = 32;
pub const CLASSES: usize = 10;

/// Number of LUT-routed layers in the network.
pub const N_LAYERS: usize = 4;
/// Canonical layer names, in forward order — the index space shared by
/// [`LayerLuts`], the compile pass and every plan artifact.
pub const LAYER_NAMES: [&str; N_LAYERS] = ["conv1", "conv2", "fc1", "fc2"];

/// One int8-product LUT per layer — the heterogeneous-multiplier view of
/// the network. Every forward path dispatches each layer's multiplies
/// through its own LUT; the historical single-LUT entry points are the
/// uniform special case ([`LayerLuts::uniform`]), so a uniform assignment
/// is *definitionally* bit-identical to the single-LUT path.
#[derive(Clone, Copy, Debug)]
pub struct LayerLuts<'a> {
    pub conv1: &'a [i32],
    pub conv2: &'a [i32],
    pub fc1: &'a [i32],
    pub fc2: &'a [i32],
}

impl<'a> LayerLuts<'a> {
    /// The same LUT on every layer (the classic homogeneous configuration).
    pub fn uniform(lut: &'a [i32]) -> LayerLuts<'a> {
        LayerLuts {
            conv1: lut,
            conv2: lut,
            fc1: lut,
            fc2: lut,
        }
    }

}

/// Multiply–accumulate count per image per layer, in [`LAYER_NAMES`]
/// order — the weights the compile pass uses to turn per-multiplier
/// energy into per-layer (and per-image) energy estimates. Derived from
/// the fixed architecture: conv layers count im2col-rows × k × out,
/// fc layers in × out.
pub fn layer_macs_per_image() -> [u64; N_LAYERS] {
    let c1h = IMG - 2; // 3x3 valid conv
    let conv1 = (c1h * c1h * 9 * C1_OUT) as u64;
    let p1 = c1h / 2; // maxpool2
    let c2h = p1 - 2;
    let conv2 = (c2h * c2h * 9 * C1_OUT * C2_OUT) as u64;
    let p2 = c2h / 2;
    let flat = p2 * p2 * C2_OUT;
    let fc1 = (flat * FC1_OUT) as u64;
    let fc2 = (FC1_OUT * CLASSES) as u64;
    [conv1, conv2, fc1, fc2]
}

fn im2col_gen<T: Copy>(
    input: &[T],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    zero: T,
) -> (Vec<T>, usize, usize) {
    // input layout HWC; output rows = (h-k+1)*(w-k+1), cols = k*k*c
    let oh = h - k + 1;
    let ow = w - k + 1;
    let cols = k * k * c;
    let mut out = vec![zero; oh * ow * cols];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut idx = 0;
            for ky in 0..k {
                for kx in 0..k {
                    for ch in 0..c {
                        out[row * cols + idx] = input[((oy + ky) * w + (ox + kx)) * c + ch];
                        idx += 1;
                    }
                }
            }
        }
    }
    (out, oh * ow, cols)
}

fn im2col(input: &[f32], h: usize, w: usize, c: usize, k: usize) -> (Vec<f32>, usize, usize) {
    im2col_gen(input, h, w, c, k, 0f32)
}

/// Batch-of-N im2col over *already quantized* activations: images are
/// stacked along the row axis, so one GEMM covers the whole batch and
/// every weight tile is reused `N` times. Operating on i8 after
/// quantization is bit-equivalent to the scalar path's quantize-after-
/// im2col (im2col only copies elements, and quantization is a pure
/// per-element map), but quantizes each activation once instead of once
/// per patch it appears in (~k·k times).
/// Returns (matrix, rows per image, cols); total rows = `batch * rows`.
fn im2col_batch_i8(
    input: &[i8],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
) -> (Vec<i8>, usize, usize) {
    let per_image = h * w * c;
    assert_eq!(input.len(), batch * per_image);
    let oh = h - k + 1;
    let ow = w - k + 1;
    let cols = k * k * c;
    let mut out = Vec::with_capacity(batch * oh * ow * cols);
    let mut rows = oh * ow;
    for i in 0..batch {
        let (one, m, _) = im2col_gen(&input[i * per_image..(i + 1) * per_image], h, w, c, k, 0i8);
        rows = m;
        out.extend_from_slice(&one);
    }
    (out, rows, cols)
}

fn relu(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

fn maxpool2(input: &[f32], h: usize, w: usize, c: usize) -> (Vec<f32>, usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![f32::MIN; oh * ow * c];
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut m = f32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(input[((2 * y + dy) * w + (2 * x + dx)) * c + ch]);
                    }
                }
                out[(y * ow + x) * c + ch] = m;
            }
        }
    }
    (out, oh, ow)
}

impl QuantCnn {
    /// Quantized conv/fc as im2col + LUT matmul + bias.
    fn layer_forward(
        &self,
        lut: &[i32],
        layer: &QuantLayer,
        input: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let a_q = quantize_all(input, layer.in_scale);
        let mut out = lut_matmul(lut, &a_q, &layer.w_q, m, k, n, layer.in_scale, layer.w_scale);
        for row in 0..m {
            for j in 0..n {
                out[row * n + j] += layer.bias[j];
            }
        }
        out
    }

    /// Forward one image (u8 16×16 grayscale) → 10 logits.
    pub fn forward(&self, lut: &[i32], image: &[u8]) -> Vec<f32> {
        self.forward_hetero(&LayerLuts::uniform(lut), image)
    }

    /// [`QuantCnn::forward`] with a per-layer LUT assignment: each layer's
    /// multiplies go through its own LUT. With [`LayerLuts::uniform`] this
    /// *is* `forward` (same code path).
    pub fn forward_hetero(&self, luts: &LayerLuts, image: &[u8]) -> Vec<f32> {
        assert_eq!(image.len(), IMG * IMG);
        // Normalize to [0,1].
        let x: Vec<f32> = image.iter().map(|&p| p as f32 / 255.0).collect();
        // conv1
        let (cols, m, k) = im2col(&x, IMG, IMG, 1, 3);
        let mut h1 = self.layer_forward(luts.conv1, &self.conv1, &cols, m, k, C1_OUT);
        relu(&mut h1);
        let (p1, h1h, h1w) = maxpool2(&h1, IMG - 2, IMG - 2, C1_OUT);
        // conv2
        let (cols2, m2, k2) = im2col(&p1, h1h, h1w, C1_OUT, 3);
        let mut h2 = self.layer_forward(luts.conv2, &self.conv2, &cols2, m2, k2, C2_OUT);
        relu(&mut h2);
        let (p2, p2h, p2w) = maxpool2(&h2, h1h - 2, h1w - 2, C2_OUT);
        // flatten → fc1 → fc2
        let flat_len = p2h * p2w * C2_OUT;
        let mut h3 = self.layer_forward(luts.fc1, &self.fc1, &p2, 1, flat_len, FC1_OUT);
        relu(&mut h3);
        self.layer_forward(luts.fc2, &self.fc2, &h3, 1, FC1_OUT, CLASSES)
    }

    /// Batched [`QuantCnn::layer_forward`] over pre-quantized activations:
    /// identical math, one blocked GEMM over all rows of the whole batch.
    #[allow(clippy::too_many_arguments)]
    fn layer_forward_batched_q(
        &self,
        lut: &[i32],
        layer: &QuantLayer,
        a_q: &[i8],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
    ) -> Vec<f32> {
        let mut out = lut_matmul_batched(
            lut,
            a_q,
            &layer.w_q,
            m,
            k,
            n,
            layer.in_scale,
            layer.w_scale,
            threads,
        );
        for row in 0..m {
            for j in 0..n {
                out[row * n + j] += layer.bias[j];
            }
        }
        out
    }

    /// The batched pipeline for one contiguous image group; `gemm_threads`
    /// parallelizes inside the GEMMs only (see [`QuantCnn::forward_batch`]
    /// for the group-level split).
    fn forward_batch_core(
        &self,
        luts: &LayerLuts,
        images: &[&[u8]],
        gemm_threads: usize,
    ) -> Vec<Vec<f32>> {
        let bsz = images.len();
        // Normalize + quantize the whole batch once, BEFORE im2col:
        // im2col only copies elements and quantization is a pure
        // per-element map, so quantize∘im2col == im2col∘quantize — but
        // this way each activation quantizes once, not once per patch.
        let mut xq = Vec::with_capacity(bsz * IMG * IMG);
        for img in images {
            assert_eq!(img.len(), IMG * IMG);
            xq.extend(
                img.iter()
                    .map(|&p| quantize(p as f32 / 255.0, self.conv1.in_scale)),
            );
        }
        // conv1 over the stacked batch: weight tiles reused across images.
        let (a1, m1, k1) = im2col_batch_i8(&xq, bsz, IMG, IMG, 1, 3);
        let mut h1 = self.layer_forward_batched_q(
            luts.conv1,
            &self.conv1,
            &a1,
            bsz * m1,
            k1,
            C1_OUT,
            gemm_threads,
        );
        relu(&mut h1);
        let (c1h, c1w) = (IMG - 2, IMG - 2);
        let per1 = c1h * c1w * C1_OUT;
        let mut p1 = Vec::with_capacity(bsz * per1 / 4);
        let (mut p1h, mut p1w) = (1, 1);
        for i in 0..bsz {
            let (p, hh, ww) = maxpool2(&h1[i * per1..(i + 1) * per1], c1h, c1w, C1_OUT);
            p1h = hh;
            p1w = ww;
            p1.extend_from_slice(&p);
        }
        // conv2 over the stacked batch.
        let p1q = quantize_all(&p1, self.conv2.in_scale);
        let (a2, m2, k2) = im2col_batch_i8(&p1q, bsz, p1h, p1w, C1_OUT, 3);
        let mut h2 = self.layer_forward_batched_q(
            luts.conv2,
            &self.conv2,
            &a2,
            bsz * m2,
            k2,
            C2_OUT,
            gemm_threads,
        );
        relu(&mut h2);
        let (c2h, c2w) = (p1h - 2, p1w - 2);
        let per2 = c2h * c2w * C2_OUT;
        let mut p2 = Vec::with_capacity(bsz * per2 / 4);
        let (mut p2h, mut p2w) = (1, 1);
        for i in 0..bsz {
            let (p, hh, ww) = maxpool2(&h2[i * per2..(i + 1) * per2], c2h, c2w, C2_OUT);
            p2h = hh;
            p2w = ww;
            p2.extend_from_slice(&p);
        }
        // fc1/fc2: one GEMM row per image.
        let flat_len = p2h * p2w * C2_OUT;
        let p2q = quantize_all(&p2, self.fc1.in_scale);
        let mut h3 = self.layer_forward_batched_q(
            luts.fc1,
            &self.fc1,
            &p2q,
            bsz,
            flat_len,
            FC1_OUT,
            gemm_threads,
        );
        relu(&mut h3);
        let h3q = quantize_all(&h3, self.fc2.in_scale);
        let logits = self.layer_forward_batched_q(
            luts.fc2,
            &self.fc2,
            &h3q,
            bsz,
            FC1_OUT,
            CLASSES,
            gemm_threads,
        );
        logits.chunks(CLASSES).map(|row| row.to_vec()).collect()
    }

    /// Forward a batch of images (each a 256-byte 16×16 grayscale) in one
    /// pass: conv layers run as a single blocked GEMM over the stacked
    /// batch-of-N im2col matrix (weight tiles reused across the batch), fc
    /// layers as one GEMM with one row per image.
    ///
    /// With `threads > 1` the batch splits into contiguous image groups,
    /// one per worker, and each group runs the whole pipeline (quantize,
    /// im2col, GEMM, pool) serially — so every stage scales with cores,
    /// not just the GEMM inner loops. A single image with spare threads
    /// instead parallelizes over GEMM row-tiles.
    ///
    /// **Bit-identical** to calling [`QuantCnn::forward`] per image, for
    /// every LUT, batch size, grouping and thread count: each output row's
    /// integer accumulation sums the same products (order-independent),
    /// and every float op (normalize, quantize, bias add, relu, maxpool,
    /// dequantize) is applied per element exactly as in the scalar path.
    /// The equivalence suite (`rust/tests/nn_batch_equivalence.rs`) pins
    /// this down.
    pub fn forward_batch(&self, lut: &[i32], images: &[&[u8]], threads: usize) -> Vec<Vec<f32>> {
        self.forward_batch_hetero(&LayerLuts::uniform(lut), images, threads)
    }

    /// [`QuantCnn::forward_batch`] with a per-layer LUT assignment — the
    /// execution path for compiled heterogeneous plans. Bit-identical to
    /// [`QuantCnn::forward_hetero`] per image for any batch size, grouping
    /// and thread count (same argument as the uniform case: integer
    /// accumulation per output element is order-independent, float ops are
    /// per-element identical), and with [`LayerLuts::uniform`] it *is*
    /// `forward_batch`.
    pub fn forward_batch_hetero(
        &self,
        luts: &LayerLuts,
        images: &[&[u8]],
        threads: usize,
    ) -> Vec<Vec<f32>> {
        let bsz = images.len();
        if bsz == 0 {
            return Vec::new();
        }
        let threads = threads.max(1);
        if threads == 1 || bsz == 1 {
            return self.forward_batch_core(luts, images, threads);
        }
        let groups = threads.min(bsz);
        let base = bsz / groups;
        let rem = bsz % groups;
        let grouped = parallel_map(groups, threads, |g| {
            let start = g * base + g.min(rem);
            let len = base + usize::from(g < rem);
            self.forward_batch_core(luts, &images[start..start + len], 1)
        });
        grouped.into_iter().flatten().collect()
    }

    /// Load from the artifacts directory written by `python/compile/aot.py`
    /// (weights/{name}_q.npy int8-as-i32, weights/{name}_b.npy f32, and
    /// weights/scales.npy = [in1, w1, in2, w2, in3, w3, in4, w4]).
    pub fn load(dir: &Path) -> Result<QuantCnn> {
        let wdir = dir.join("weights");
        let (_, scales) = npy::read_f32(&wdir.join("scales.npy"))
            .context("reading scales.npy — run `make artifacts` first")?;
        if scales.len() != 8 {
            bail!("scales.npy must have 8 entries, got {}", scales.len());
        }
        let load_layer = |name: &str, in_scale: f32, w_scale: f32| -> Result<QuantLayer> {
            let (_, wq) = npy::read_i32(&wdir.join(format!("{name}_q.npy")))?;
            let (_, bias) = npy::read_f32(&wdir.join(format!("{name}_b.npy")))?;
            Ok(QuantLayer {
                w_q: wq.iter().map(|&v| v as i8).collect(),
                w_scale,
                in_scale,
                bias,
            })
        };
        Ok(QuantCnn {
            conv1: load_layer("conv1", scales[0], scales[1])?,
            conv2: load_layer("conv2", scales[2], scales[3])?,
            fc1: load_layer("fc1", scales[4], scales[5])?,
            fc2: load_layer("fc2", scales[6], scales[7])?,
        })
    }

    /// A tiny deterministic random model (for tests without artifacts).
    pub fn random(seed: u64) -> QuantCnn {
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let mut mk = |k: usize, n: usize, in_scale: f32| -> QuantLayer {
            let w_q: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(255) as i64 - 127) as i8)
                .collect();
            QuantLayer {
                w_q,
                w_scale: 0.02,
                in_scale,
                bias: (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.1).collect(),
            }
        };
        QuantCnn {
            conv1: mk(9, C1_OUT, 1.0 / 127.0),
            conv2: mk(72, C2_OUT, 0.05),
            fc1: mk(64, FC1_OUT, 0.05),
            fc2: mk(FC1_OUT, CLASSES, 0.05),
        }
    }
}

/// `n` deterministic pseudo-random 16×16 grayscale images (flattened to
/// `n * 256` bytes) — the artifact-free workload for benches, the serving
/// soak test, and `--backend native` demos without a dataset on disk.
pub fn synthetic_images(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = crate::util::rng::Pcg32::new(seed);
    (0..n * IMG * IMG).map(|_| rng.below(256) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;
    use crate::mult::behavioral::int8_lut;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn forward_shapes_and_determinism() {
        let cnn = QuantCnn::random(7);
        let lut = int8_lut(&MultFamily::Exact);
        let img: Vec<u8> = (0..256).map(|i| (i * 7 % 256) as u8).collect();
        let a = cnn.forward(&lut, &img);
        let b = cnn.forward(&lut, &img);
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn different_luts_give_close_but_different_logits() {
        let cnn = QuantCnn::random(3);
        let exact = int8_lut(&MultFamily::Exact);
        let logour = int8_lut(&MultFamily::LogOur);
        let img: Vec<u8> = (0..256).map(|i| ((i * 13) % 256) as u8).collect();
        let le = cnn.forward(&exact, &img);
        let ll = cnn.forward(&logour, &img);
        assert_ne!(le, ll);
        let scale: f32 = le.iter().map(|x| x.abs()).sum::<f32>() / 10.0;
        let dev: f32 = le
            .iter()
            .zip(&ll)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / 10.0;
        assert!(dev < 0.5 * scale, "dev {dev} vs scale {scale}");
    }

    #[test]
    fn forward_batch_matches_forward_small() {
        // Debug-friendly bit-exactness smoke (the full family × batch-size
        // matrix lives in rust/tests/nn_batch_equivalence.rs).
        let cnn = QuantCnn::random(7);
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let images = synthetic_images(2, 3);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let batched = cnn.forward_batch(&lut, &views, 2);
        assert_eq!(batched.len(), 2);
        for (i, v) in views.iter().enumerate() {
            assert_eq!(batched[i], cnn.forward(&lut, v), "image {i}");
        }
    }

    #[test]
    fn layer_macs_match_architecture() {
        // conv1: 14·14 patches × 9 taps × 8 out; conv2: 5·5 × 72 × 16;
        // fc1: 64×32; fc2: 32×10.
        assert_eq!(layer_macs_per_image(), [14112, 28800, 2048, 320]);
    }

    #[test]
    fn hetero_uniform_is_bit_identical_to_uniform() {
        let cnn = QuantCnn::random(11);
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let images = synthetic_images(3, 9);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let uniform = cnn.forward_batch(&lut, &views, 2);
        let hetero = cnn.forward_batch_hetero(&LayerLuts::uniform(&lut), &views, 2);
        assert_eq!(uniform, hetero);
        assert_eq!(
            cnn.forward(&lut, views[0]),
            cnn.forward_hetero(&LayerLuts::uniform(&lut), views[0])
        );
    }

    #[test]
    fn hetero_layer_swap_changes_only_that_layer_path() {
        // Swapping fc2's LUT to all-zeros must leave conv/fc1 outputs
        // intact: logits collapse to exactly the fc2 biases.
        let cnn = QuantCnn::random(4);
        let mut exact = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                exact[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let zero = vec![0i32; 65536];
        let images = synthetic_images(2, 21);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let luts = LayerLuts {
            conv1: &exact,
            conv2: &exact,
            fc1: &exact,
            fc2: &zero,
        };
        for row in cnn.forward_batch_hetero(&luts, &views, 1) {
            for (j, v) in row.iter().enumerate() {
                assert_eq!(*v, cnn.fc2.bias[j]);
            }
        }
    }

    #[test]
    fn im2col_batch_stacks_per_image_blocks() {
        let x: Vec<i8> = (1..=18).collect(); // two 3x3 images
        let (cols, m, k) = super::im2col_batch_i8(&x, 2, 3, 3, 1, 2);
        assert_eq!((m, k), (4, 4));
        assert_eq!(cols.len(), 2 * 4 * 4);
        let (one, _, _) = super::im2col_gen(&x[0..9], 3, 3, 1, 2, 0i8);
        let (two, _, _) = super::im2col_gen(&x[9..18], 3, 3, 1, 2, 0i8);
        assert_eq!(&cols[0..16], &one[..]);
        assert_eq!(&cols[16..32], &two[..]);
    }

    #[test]
    fn im2col_reference() {
        // 3x3 single-channel input, k=2 → 4 rows of 4 values.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let (cols, m, k) = super::im2col(&x, 3, 3, 1, 2);
        assert_eq!((m, k), (4, 4));
        assert_eq!(&cols[0..4], &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(&cols[12..16], &[5.0, 6.0, 8.0, 9.0]);
    }

    #[test]
    fn maxpool_reference() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let (p, h, w) = super::maxpool2(&x, 2, 2, 1);
        assert_eq!((h, w), (1, 1));
        assert_eq!(p, vec![4.0]);
    }
}
